//! §2 — classic single-source DLT with the recursive closed form.
//!
//! Timing model of Fig. 2: the source sends `β_1..β_M` back-to-back;
//! processor `P_i` computes only after fully receiving its fraction
//! (no front-end), and all processors finish simultaneously:
//!
//! `T_f = Σ_{k≤i} β_k G + β_i A_i` for every `i`, `Σ β_i = J`.
//!
//! Subtracting consecutive equations gives the recursion
//! `β_{i+1} = β_i · A_i / (G + A_{i+1})`.

use crate::dlt::schedule::{Schedule, TimingModel};
use crate::error::{Error, Result};
use crate::linalg::{lu_solve, Matrix};

/// Closed-form solution. Returns the fully-timed [`Schedule`]
/// (communication windows are back-to-back starting at `release`).
pub fn solve(g: f64, a: &[f64], job: f64, release: f64) -> Result<Schedule> {
    if !(g > 0.0) {
        return Err(Error::InvalidSpec(format!("G must be > 0, got {g}")));
    }
    if a.is_empty() {
        return Err(Error::InvalidSpec("need at least one processor".into()));
    }
    if a.iter().any(|&x| !(x > 0.0)) {
        return Err(Error::InvalidSpec("all A_j must be > 0".into()));
    }
    if !(job > 0.0) {
        return Err(Error::InvalidSpec("job must be > 0".into()));
    }
    let m = a.len();
    // Unnormalized fractions via the recursion.
    let mut beta = vec![0.0; m];
    beta[0] = 1.0;
    for i in 1..m {
        beta[i] = beta[i - 1] * a[i - 1] / (g + a[i]);
    }
    let total: f64 = beta.iter().sum();
    for b in beta.iter_mut() {
        *b *= job / total;
    }
    let tf = release + beta[0] * (g + a[0]);

    // Timed windows.
    let mut comm_start = vec![0.0; m];
    let mut comm_end = vec![0.0; m];
    let mut t = release;
    for j in 0..m {
        comm_start[j] = t;
        t += beta[j] * g;
        comm_end[j] = t;
    }
    let compute_start = comm_end.clone();
    let compute_end: Vec<f64> = (0..m).map(|j| comm_end[j] + beta[j] * a[j]).collect();

    Ok(Schedule {
        n: 1,
        m,
        model: TimingModel::NoFrontEnd,
        beta,
        comm_start,
        comm_end,
        compute_start,
        compute_end,
        makespan: tf,
        lp_iterations: 0,
    })
}

/// Oracle variant: solve the `(M+1) × (M+1)` linear system of §2
/// directly with LU. Exists purely to cross-check the recursion.
pub fn solve_linear_system(g: f64, a: &[f64], job: f64) -> Result<(Vec<f64>, f64)> {
    let m = a.len();
    // Unknowns: beta_0..beta_{m-1}, T_f.
    let n = m + 1;
    let mut mat = Matrix::zeros(n, n);
    let mut rhs = vec![0.0; n];
    for i in 0..m {
        // sum_{k<=i} beta_k * G + beta_i * A_i - T_f = 0
        for k in 0..=i {
            mat[(i, k)] += g;
        }
        mat[(i, i)] += a[i];
        mat[(i, m)] = -1.0;
    }
    // normalization
    for k in 0..m {
        mat[(m, k)] = 1.0;
    }
    rhs[m] = job;
    let x = lu_solve(&mat, &rhs)?;
    Ok((x[..m].to_vec(), x[m]))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::float::approx_eq_eps;

    #[test]
    fn homogeneous_two_processors() {
        // G=1, A=[1,1], J=1: beta2 = beta1 * 1/(1+1) = beta1/2
        // => beta = [2/3, 1/3], T_f = (2/3)(1+1) = 4/3.
        let s = solve(1.0, &[1.0, 1.0], 1.0, 0.0).unwrap();
        assert!(approx_eq_eps(s.beta[0], 2.0 / 3.0, 1e-12, 1e-12));
        assert!(approx_eq_eps(s.beta[1], 1.0 / 3.0, 1e-12, 1e-12));
        assert!(approx_eq_eps(s.makespan, 4.0 / 3.0, 1e-12, 1e-12));
    }

    #[test]
    fn all_processors_finish_simultaneously() {
        let s = solve(0.3, &[1.0, 1.5, 2.0, 4.0], 50.0, 0.0).unwrap();
        for j in 0..s.m {
            assert!(
                approx_eq_eps(s.compute_end[j], s.makespan, 1e-9, 1e-9),
                "P{j} ends at {} != {}",
                s.compute_end[j],
                s.makespan
            );
        }
    }

    #[test]
    fn matches_linear_system_oracle() {
        let g = 0.2;
        let a = [2.0, 3.0, 4.0, 5.0, 6.0];
        let s = solve(g, &a, 100.0, 0.0).unwrap();
        let (beta, tf) = solve_linear_system(g, &a, 100.0).unwrap();
        assert!(approx_eq_eps(s.makespan, tf, 1e-9, 1e-9), "{} vs {tf}", s.makespan);
        for (b1, b2) in s.beta.iter().zip(beta.iter()) {
            assert!(approx_eq_eps(*b1, *b2, 1e-9, 1e-9));
        }
    }

    #[test]
    fn release_time_shifts_everything() {
        let s0 = solve(0.5, &[1.0, 2.0], 10.0, 0.0).unwrap();
        let s5 = solve(0.5, &[1.0, 2.0], 10.0, 5.0).unwrap();
        assert!(approx_eq_eps(s5.makespan, s0.makespan + 5.0, 1e-12, 1e-12));
        assert_eq!(s5.beta, s0.beta);
    }

    #[test]
    fn faster_processors_get_more_load() {
        let s = solve(0.2, &[1.0, 2.0, 4.0], 30.0, 0.0).unwrap();
        assert!(s.beta[0] > s.beta[1]);
        assert!(s.beta[1] > s.beta[2]);
    }

    #[test]
    fn adding_processors_reduces_makespan() {
        let mut prev = f64::INFINITY;
        let a: Vec<f64> = (0..8).map(|k| 1.0 + 0.2 * k as f64).collect();
        for m in 1..=8 {
            let s = solve(0.4, &a[..m], 100.0, 0.0).unwrap();
            assert!(s.makespan < prev, "m={m}: {} !< {prev}", s.makespan);
            prev = s.makespan;
        }
    }

    #[test]
    fn normalization_holds() {
        let s = solve(0.7, &[1.1, 1.2, 1.3], 42.0, 0.0).unwrap();
        assert!(approx_eq_eps(s.total_load(), 42.0, 1e-9, 1e-9));
    }

    #[test]
    fn rejects_bad_inputs() {
        assert!(solve(0.0, &[1.0], 1.0, 0.0).is_err());
        assert!(solve(1.0, &[], 1.0, 0.0).is_err());
        assert!(solve(1.0, &[0.0], 1.0, 0.0).is_err());
        assert!(solve(1.0, &[1.0], 0.0, 0.0).is_err());
    }
}
