//! Extension (paper §8, "simultaneous communication with a bandwidth
//! limitation"): concurrent distribution under a per-source bandwidth
//! cap, in two fluid models.
//!
//! Each source `S_i` has fixed bandwidth `b_i = 1/G_i` but may serve
//! several processors at once (and a processor may receive from several
//! sources at once) — the paper's sequential-communication rules are
//! lifted, only the bandwidth cap remains.
//!
//! **Proportional** — the source splits `b_i` proportionally to its
//! fraction sizes, so all of its streams finish together at
//! `D_i = R_i + α_i G_i` (`α_i = Σ_j β_{i,j}`). Two extra LP variables.
//!
//! **Staggered** — the source schedules its outgoing fluid freely
//! (water-filling); a set of per-stream completion deadlines
//! `t_{i,1} ≤ … ≤ t_{i,M}` is achievable iff the cumulative demand
//! meets the capacity: `Σ_{k≤j} β_{i,k} G_i ≤ t_{i,j} − R_i` (EDF
//! feasibility for fluid streams). This strictly generalizes both the
//! proportional model and the paper's sequential protocol, so its
//! optimum dominates both.
//!
//! Measured on the paper's Table 3 (see `bench_ablations`):
//! proportional wins over sequential only for small `m` (everyone
//! waiting for the common drain time wastes the early-start advantage
//! as `m` grows — a finding the paper's future-work section does not
//! anticipate), while staggered concurrency dominates everywhere.

use crate::dlt::schedule::{Schedule, TimingModel};
use crate::error::Result;
use crate::lp::{Cmp, LpProblem, LpSolution};
use crate::model::SystemSpec;
use crate::pipeline::ScenarioModel;

/// Which fluid model to use.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Mode {
    /// Equal-finish proportional bandwidth sharing.
    Proportional,
    /// Free (EDF/water-filling) bandwidth scheduling.
    #[default]
    Staggered,
}

/// Options for the §8 concurrent-distribution builders — and the
/// family's [`ScenarioModel`]. Solver/backend tuning lives in
/// [`crate::pipeline::PipelineOptions`] (or the [`crate::api`]
/// request).
#[derive(Debug, Clone, Default)]
pub struct ConcurrentOptions {
    /// Fluid model.
    pub mode: Mode,
}

impl ScenarioModel for ConcurrentOptions {
    fn name(&self) -> &'static str {
        "concurrent"
    }

    fn build_lp(&self, spec: &SystemSpec) -> LpProblem {
        build_lp(spec, self.mode)
    }

    fn schedule(&self, spec: &SystemSpec, sol: &LpSolution) -> Result<Schedule> {
        schedule_from_solution(spec, self.mode, sol)
    }
}

/// Build the concurrent-distribution LP (no-front-end semantics).
pub fn build_lp(spec: &SystemSpec, mode: Mode) -> LpProblem {
    match mode {
        Mode::Proportional => build_proportional(spec),
        Mode::Staggered => build_staggered(spec),
    }
}

fn build_proportional(spec: &SystemSpec) -> LpProblem {
    let n = spec.n();
    let m = spec.m();
    let g = spec.g();
    let r = spec.releases();
    let a = spec.a();
    let d = n * m; // shared arrival-deadline variable
    let tf = n * m + 1;
    let mut p = LpProblem::new(n * m + 2);
    for i in 0..n {
        for j in 0..m {
            p.name_var(i * m + j, format!("beta[{i}][{j}]"));
        }
    }
    p.name_var(d, "D");
    p.name_var(tf, "T_f");
    p.set_objective_coeff(tf, 1.0);

    // D >= R_i + alpha_i G_i
    for i in 0..n {
        let mut coeffs: Vec<(usize, f64)> = vec![(d, 1.0)];
        for j in 0..m {
            coeffs.push((i * m + j, -g[i]));
        }
        p.add_labeled(&coeffs, Cmp::Ge, r[i], format!("arrival[{i}]"));
    }
    // T_f >= D + sum_i beta[i][j] A_j
    for j in 0..m {
        let mut coeffs: Vec<(usize, f64)> = vec![(tf, 1.0), (d, -1.0)];
        for i in 0..n {
            coeffs.push((i * m + j, -a[j]));
        }
        p.add_labeled(&coeffs, Cmp::Ge, 0.0, format!("finish[{j}]"));
    }
    normalize(&mut p, spec);
    p
}

fn build_staggered(spec: &SystemSpec) -> LpProblem {
    let n = spec.n();
    let m = spec.m();
    let g = spec.g();
    let r = spec.releases();
    let a = spec.a();
    // Variables: beta (n*m), t (n*m, per-stream completion), T_f.
    let tvar = |i: usize, j: usize| n * m + i * m + j;
    let tf = 2 * n * m;
    let mut p = LpProblem::new(2 * n * m + 1);
    for i in 0..n {
        for j in 0..m {
            p.name_var(i * m + j, format!("beta[{i}][{j}]"));
            p.name_var(tvar(i, j), format!("t[{i}][{j}]"));
        }
    }
    p.name_var(tf, "T_f");
    p.set_objective_coeff(tf, 1.0);

    for i in 0..n {
        for j in 0..m {
            // Deadline ordering (paper convention: fast processors first).
            if j + 1 < m {
                p.add_labeled(
                    &[(tvar(i, j), 1.0), (tvar(i, j + 1), -1.0)],
                    Cmp::Le,
                    0.0,
                    format!("order[{i}][{j}]"),
                );
            }
            // EDF capacity: sum_{k<=j} beta[i][k] G_i <= t[i][j] - R_i.
            let mut coeffs: Vec<(usize, f64)> = vec![(tvar(i, j), 1.0)];
            for k in 0..=j {
                coeffs.push((i * m + k, -g[i]));
            }
            p.add_labeled(&coeffs, Cmp::Ge, r[i], format!("capacity[{i}][{j}]"));
            // Finish: T_f >= t[i][j] + sum_k beta[k][j] A_j.
            // (For beta[i][j] = 0 streams this still ties t >= R_i into
            // the bound — same zero-window artifact the paper's own
            // §3.2 LP has; negligible when releases are small.)
            let mut coeffs: Vec<(usize, f64)> = vec![(tf, 1.0), (tvar(i, j), -1.0)];
            for k in 0..n {
                coeffs.push((k * m + j, -a[j]));
            }
            p.add_labeled(&coeffs, Cmp::Ge, 0.0, format!("finish[{i}][{j}]"));
        }
    }
    normalize(&mut p, spec);
    p
}

fn normalize(p: &mut LpProblem, spec: &SystemSpec) {
    let (n, m) = (spec.n(), spec.m());
    let all: Vec<(usize, f64)> =
        (0..n).flat_map(|i| (0..m).map(move |j| (i * m + j, 1.0))).collect();
    p.add_labeled(&all, Cmp::Eq, spec.job, "normalize");
}

/// Reconstruct the timed schedule from an LP solution of the §8 LPs.
fn schedule_from_solution(spec: &SystemSpec, mode: Mode, sol: &LpSolution) -> Result<Schedule> {
    let n = spec.n();
    let m = spec.m();
    let g = spec.g();
    let r = spec.releases();
    let a = spec.a();

    let beta: Vec<f64> = sol.x[..n * m]
        .iter()
        .map(|&b| crate::util::float::snap_nonneg(b, 1e-9))
        .collect();
    let makespan = *sol.x.last().unwrap();

    // Per-stream completion times.
    let t_ij: Vec<f64> = match mode {
        Mode::Proportional => {
            let alpha: Vec<f64> =
                (0..n).map(|i| (0..m).map(|j| beta[i * m + j]).sum()).collect();
            (0..n * m).map(|k| r[k / m] + alpha[k / m] * g[k / m]).collect()
        }
        Mode::Staggered => sol.x[n * m..2 * n * m].to_vec(),
    };

    // Bandwidth-equivalent windows ending at the completion time.
    let mut comm_start = vec![0.0; n * m];
    let mut comm_end = vec![0.0; n * m];
    for i in 0..n {
        for j in 0..m {
            let k = i * m + j;
            comm_end[k] = t_ij[k];
            comm_start[k] = t_ij[k] - beta[k] * g[i];
        }
    }
    let mut compute_start = vec![0.0; m];
    let mut compute_end = vec![0.0; m];
    for j in 0..m {
        let total: f64 = (0..n).map(|i| beta[i * m + j]).sum();
        let arrive = (0..n)
            .filter(|&i| beta[i * m + j] > 1e-12)
            .map(|i| t_ij[i * m + j])
            .fold(0.0f64, f64::max);
        compute_start[j] = arrive;
        compute_end[j] = arrive + total * a[j];
    }

    Ok(Schedule {
        n,
        m,
        model: TimingModel::NoFrontEnd,
        beta,
        comm_start,
        comm_end,
        compute_start,
        compute_end,
        makespan,
        lp_iterations: sol.iterations,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dlt::no_frontend::NfeOptions;
    use crate::experiments::params;

    // The per-family forwards are gone (PR 4): solve through the
    // pipeline.
    fn solve_mode(spec: &SystemSpec, mode: Mode) -> Result<Schedule> {
        crate::pipeline::solve(&ConcurrentOptions { mode }, spec)
    }

    fn nfe_solve(spec: &SystemSpec) -> Result<Schedule> {
        crate::pipeline::solve(&NfeOptions::default(), spec)
    }

    #[test]
    fn staggered_dominates_sequential() {
        // The §8 hypothesis, in the model that subsumes the sequential
        // protocol: simultaneous communication can only help.
        let spec = params::table3();
        for mprocs in [2usize, 5, 10, 20] {
            let sub = spec.with_m_processors(mprocs);
            let seq = nfe_solve(&sub).unwrap();
            let con = solve_mode(&sub, Mode::Staggered).unwrap();
            assert!(
                con.makespan <= seq.makespan + 1e-6,
                "m={mprocs}: staggered {} > sequential {}",
                con.makespan,
                seq.makespan
            );
        }
    }

    #[test]
    fn staggered_dominates_proportional() {
        let spec = params::table3();
        for mprocs in [2usize, 6, 12] {
            let sub = spec.with_m_processors(mprocs);
            let prop = solve_mode(&sub, Mode::Proportional).unwrap();
            let stag = solve_mode(&sub, Mode::Staggered).unwrap();
            assert!(
                stag.makespan <= prop.makespan + 1e-6,
                "m={mprocs}: staggered {} > proportional {}",
                stag.makespan,
                prop.makespan
            );
        }
    }

    #[test]
    fn proportional_crossover_documented() {
        // Proportional sharing helps at small m but *hurts* at large m
        // (everyone waits for the common drain) — the finding recorded
        // in EXPERIMENTS.md.
        let spec = params::table3();
        let seq_small = nfe_solve(&spec.with_m_processors(1)).unwrap().makespan;
        let prop_small =
            solve_mode(&spec.with_m_processors(1), Mode::Proportional).unwrap().makespan;
        assert!(prop_small < seq_small, "{prop_small} !< {seq_small}");
        let seq_large = nfe_solve(&spec.with_m_processors(20)).unwrap().makespan;
        let prop_large =
            solve_mode(&spec.with_m_processors(20), Mode::Proportional).unwrap().makespan;
        assert!(prop_large > seq_large, "{prop_large} !> {seq_large}");
    }

    #[test]
    fn realized_makespan_within_lp_bound() {
        let spec = params::table3().with_m_processors(8);
        for mode in [Mode::Proportional, Mode::Staggered] {
            let s = solve_mode(&spec, mode).unwrap();
            assert!(
                s.realized_makespan() <= s.makespan + 1e-6,
                "{mode:?}: realized {} > lp {}",
                s.realized_makespan(),
                s.makespan
            );
            assert!((s.total_load() - 100.0).abs() < 1e-6);
        }
    }

    #[test]
    fn staggered_capacity_respected() {
        let spec = params::table3().with_m_processors(6);
        let g = spec.g();
        let r = spec.releases();
        let s = solve_mode(&spec, Mode::Staggered).unwrap();
        for i in 0..s.n {
            let mut cum = 0.0;
            for j in 0..s.m {
                cum += s.beta(i, j) * g[i];
                let t = s.comm_end[i * s.m + j];
                assert!(
                    cum <= t - r[i] + 1e-6,
                    "source {i} overcommitted by stream {j}: {cum} > {}",
                    t - r[i]
                );
            }
        }
    }

    #[test]
    fn single_source_single_processor_closed_form() {
        // T_f = R + J G + J A (no concurrency to exploit).
        let spec = crate::model::SystemSpec::builder()
            .source(0.5, 2.0)
            .processor(1.5)
            .job(10.0)
            .build()
            .unwrap();
        for mode in [Mode::Proportional, Mode::Staggered] {
            let s = solve_mode(&spec, mode).unwrap();
            assert!((s.makespan - (2.0 + 5.0 + 15.0)).abs() < 1e-6, "{mode:?}: {}", s.makespan);
        }
    }

    #[test]
    fn improvement_grows_with_sources() {
        let spec = params::table3();
        let ratio = |n: usize| {
            let sub = spec.with_n_sources(n).with_m_processors(12);
            let seq = nfe_solve(&sub).unwrap().makespan;
            let con = solve_mode(&sub, Mode::Staggered).unwrap().makespan;
            seq / con
        };
        assert!(ratio(3) >= ratio(1) - 1e-9);
    }
}
