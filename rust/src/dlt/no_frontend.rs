//! §3.2 — multi-source scheduling for processors **without**
//! front-ends.
//!
//! LP variables: `β_{i,j}`, `TS_{i,j}`, `TF_{i,j}` (3·N·M) and `T_f`.
//! Constraints (paper eqs. 7–14):
//!
//! - length:   `TF_{i,j} − TS_{i,j} = β_{i,j} G_i`
//! - proc seq: `TF_{i,j} ≤ TS_{i+1,j}` (one receive at a time)
//! - src seq:  `TF_{i,j} ≤ TS_{i,j+1}` (one send at a time)
//! - release:  `TS_{1,1} = R_1`, `TS_{i,1} ≥ R_i`, `TF_{i−1,1} ≥ R_i`
//! - finish:   `T_f ≥ TF_{N,j} + Σ_i β_{i,j} A_j`
//! - normalize: `ΣΣ β = J`
//!
//! The paper's eq. 12 text uses a strict `>`; LPs cannot express strict
//! inequalities and the paper's own problem-summary uses `≥`, which is
//! what we implement.

use crate::dlt::schedule::{Schedule, TimingModel};
use crate::error::Result;
use crate::lp::{Cmp, LpProblem, LpSolution};
use crate::model::SystemSpec;
use crate::pipeline::ScenarioModel;

/// Options for the §3.2 builder. Solver/backend tuning lives in
/// [`crate::pipeline::PipelineOptions`] (or the [`crate::api`]
/// request) — the family carries only formulation choices.
#[derive(Debug, Clone, Default)]
pub struct NfeOptions {
    /// Enforce `TF_{i−1,1} ≥ R_i` ("keep every source busy before the
    /// next one becomes available", eq. 12). On by default to match the
    /// paper; can be disabled to study its effect (it can make
    /// instances infeasible when a slow first source cannot stretch its
    /// first transmission long enough).
    pub drop_source_busy_constraint: bool,
}

/// Variable indexing for the §3.2 LP.
#[derive(Debug, Clone, Copy)]
pub struct NfeVars {
    n: usize,
    m: usize,
}

impl NfeVars {
    /// Create an index helper.
    pub fn new(n: usize, m: usize) -> NfeVars {
        NfeVars { n, m }
    }
    /// `β_{i,j}`
    pub fn beta(&self, i: usize, j: usize) -> usize {
        i * self.m + j
    }
    /// `TS_{i,j}`
    pub fn ts(&self, i: usize, j: usize) -> usize {
        self.n * self.m + i * self.m + j
    }
    /// `TF_{i,j}`
    pub fn tf(&self, i: usize, j: usize) -> usize {
        2 * self.n * self.m + i * self.m + j
    }
    /// `T_f`
    pub fn makespan(&self) -> usize {
        3 * self.n * self.m
    }
    /// Total LP variable count.
    pub fn count(&self) -> usize {
        3 * self.n * self.m + 1
    }
}

/// Build the §3.2 LP for a (validated, sorted) spec.
pub fn build_lp(spec: &SystemSpec, opts: &NfeOptions) -> LpProblem {
    let n = spec.n();
    let m = spec.m();
    let g = spec.g();
    let r = spec.releases();
    let a = spec.a();
    let v = NfeVars::new(n, m);
    let mut p = LpProblem::new(v.count());

    for i in 0..n {
        for j in 0..m {
            p.name_var(v.beta(i, j), format!("beta[{i}][{j}]"));
            p.name_var(v.ts(i, j), format!("TS[{i}][{j}]"));
            p.name_var(v.tf(i, j), format!("TF[{i}][{j}]"));
        }
    }
    p.name_var(v.makespan(), "T_f");
    p.set_objective_coeff(v.makespan(), 1.0);

    // (7) length: TF - TS - beta*G = 0
    for i in 0..n {
        for j in 0..m {
            p.add_labeled(
                &[(v.tf(i, j), 1.0), (v.ts(i, j), -1.0), (v.beta(i, j), -g[i])],
                Cmp::Eq,
                0.0,
                format!("length[{i}][{j}]"),
            );
        }
    }

    // (8) processor sequence: TF[i][j] <= TS[i+1][j]
    for i in 0..n.saturating_sub(1) {
        for j in 0..m {
            p.add_labeled(
                &[(v.tf(i, j), 1.0), (v.ts(i + 1, j), -1.0)],
                Cmp::Le,
                0.0,
                format!("proc_seq[{i}][{j}]"),
            );
        }
    }

    // (9) source sequence: TF[i][j] <= TS[i][j+1]
    for i in 0..n {
        for j in 0..m.saturating_sub(1) {
            p.add_labeled(
                &[(v.tf(i, j), 1.0), (v.ts(i, j + 1), -1.0)],
                Cmp::Le,
                0.0,
                format!("src_seq[{i}][{j}]"),
            );
        }
    }

    // (10) TS[0][0] = R_1
    p.add_labeled(&[(v.ts(0, 0), 1.0)], Cmp::Eq, r[0], "release_first");
    // (11) TS[i][0] >= R_i
    for i in 1..n {
        p.add_labeled(&[(v.ts(i, 0), 1.0)], Cmp::Ge, r[i], format!("release[{i}]"));
    }
    // (12) TF[i-1][0] >= R_i
    if !opts.drop_source_busy_constraint {
        for i in 1..n {
            p.add_labeled(&[(v.tf(i - 1, 0), 1.0)], Cmp::Ge, r[i], format!("src_busy[{i}]"));
        }
    }

    // (13) finish: T_f - TF[N-1][j] - sum_i beta[i][j] A_j >= 0
    for j in 0..m {
        let mut coeffs: Vec<(usize, f64)> = vec![(v.makespan(), 1.0), (v.tf(n - 1, j), -1.0)];
        for i in 0..n {
            coeffs.push((v.beta(i, j), -a[j]));
        }
        p.add_labeled(&coeffs, Cmp::Ge, 0.0, format!("finish[{j}]"));
    }

    // (14) normalization
    let all: Vec<(usize, f64)> =
        (0..n).flat_map(|i| (0..m).map(move |j| (v.beta(i, j), 1.0))).collect();
    p.add_labeled(&all, Cmp::Eq, spec.job, "normalize");

    p
}

/// The §3.2 scenario family: [`NfeOptions`] *is* the model.
impl ScenarioModel for NfeOptions {
    fn name(&self) -> &'static str {
        "no_frontend"
    }

    fn build_lp(&self, spec: &SystemSpec) -> LpProblem {
        build_lp(spec, self)
    }

    fn schedule(&self, spec: &SystemSpec, sol: &LpSolution) -> Result<Schedule> {
        schedule_from_solution(spec, sol)
    }
}

/// Reconstruct the full schedule from an LP solution of the §3.2 LP.
fn schedule_from_solution(spec: &SystemSpec, sol: &LpSolution) -> Result<Schedule> {
    let n = spec.n();
    let m = spec.m();
    let v = NfeVars::new(n, m);

    let a = spec.a();
    let mut beta = vec![0.0; n * m];
    let mut comm_start = vec![0.0; n * m];
    let mut comm_end = vec![0.0; n * m];
    for i in 0..n {
        for j in 0..m {
            beta[i * m + j] = crate::util::float::snap_nonneg(sol.x[v.beta(i, j)], 1e-9);
            comm_start[i * m + j] = sol.x[v.ts(i, j)];
            comm_end[i * m + j] = sol.x[v.tf(i, j)];
        }
    }
    // No front-end: compute starts after the LAST fraction arrives.
    let mut compute_start = vec![0.0; m];
    let mut compute_end = vec![0.0; m];
    for j in 0..m {
        let last_arrival = comm_end[(n - 1) * m + j];
        let total: f64 = (0..n).map(|i| beta[i * m + j]).sum();
        compute_start[j] = last_arrival;
        compute_end[j] = last_arrival + total * a[j];
    }

    Ok(Schedule {
        n,
        m,
        model: TimingModel::NoFrontEnd,
        beta,
        comm_start,
        comm_end,
        compute_start,
        compute_end,
        makespan: sol.x[v.makespan()],
        lp_iterations: sol.iterations,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::float::approx_eq_eps;

    // The per-family `solve`/`solve_opts` forwards are gone (PR 4):
    // every solve goes through the pipeline (or the `dlt::api`
    // facade).
    fn solve(spec: &SystemSpec) -> Result<Schedule> {
        crate::pipeline::solve(&NfeOptions::default(), spec)
    }

    fn solve_opts(spec: &SystemSpec, opts: &NfeOptions) -> Result<Schedule> {
        crate::pipeline::solve(opts, spec)
    }

    fn table2_spec() -> SystemSpec {
        SystemSpec::builder()
            .source(0.2, 0.0)
            .source(0.2, 5.0)
            .processors(&[2.0, 3.0, 4.0])
            .job(100.0)
            .build()
            .unwrap()
    }

    #[test]
    fn table2_solves() {
        let s = solve(&table2_spec()).unwrap();
        assert!(approx_eq_eps(s.total_load(), 100.0, 1e-7, 1e-7));
        assert!(s.makespan > 0.0);
        assert_eq!(s.model, TimingModel::NoFrontEnd);
    }

    #[test]
    fn makespan_equals_max_compute_end() {
        let s = solve(&table2_spec()).unwrap();
        assert!(
            approx_eq_eps(s.makespan, s.realized_makespan(), 1e-6, 1e-6),
            "T_f={} realized={}",
            s.makespan,
            s.realized_makespan()
        );
    }

    #[test]
    fn single_source_matches_closed_form() {
        // N=1, R=0: LP-NFE must reproduce the §2 closed form.
        let spec = SystemSpec::builder()
            .source(0.2, 0.0)
            .processors(&[2.0, 3.0, 4.0, 5.0, 6.0])
            .job(100.0)
            .build()
            .unwrap();
        let nfe = solve(&spec).unwrap();
        let cf = crate::dlt::single_source::solve(0.2, &spec.a(), 100.0, 0.0).unwrap();
        assert!(
            approx_eq_eps(nfe.makespan, cf.makespan, 1e-6, 1e-6),
            "LP {} vs closed form {}",
            nfe.makespan,
            cf.makespan
        );
        for (b_lp, b_cf) in nfe.beta.iter().zip(cf.beta.iter()) {
            assert!(approx_eq_eps(*b_lp, *b_cf, 1e-5, 1e-5), "{:?} vs {:?}", nfe.beta, cf.beta);
        }
    }

    #[test]
    fn window_lengths_match_beta() {
        let spec = table2_spec();
        let s = solve(&spec).unwrap();
        let g = spec.g();
        for i in 0..s.n {
            for j in 0..s.m {
                let k = i * s.m + j;
                assert!(approx_eq_eps(
                    s.comm_end[k] - s.comm_start[k],
                    s.beta[k] * g[i],
                    1e-6,
                    1e-6
                ));
            }
        }
    }

    #[test]
    fn sequencing_respected() {
        let s = solve(&table2_spec()).unwrap();
        for i in 0..s.n {
            for j in 0..s.m {
                let k = i * s.m + j;
                if j + 1 < s.m {
                    assert!(s.comm_end[k] <= s.comm_start[k + 1] + 1e-7, "src seq");
                }
                if i + 1 < s.n {
                    assert!(s.comm_end[k] <= s.comm_start[k + s.m] + 1e-7, "proc seq");
                }
            }
        }
    }

    #[test]
    fn release_times_respected() {
        let spec = table2_spec();
        let s = solve(&spec).unwrap();
        let r = spec.releases();
        for i in 0..s.n {
            assert!(s.comm_start[i * s.m] >= r[i] - 1e-7);
        }
        // eq. 10: TS[0][0] == R_1 exactly.
        assert!(approx_eq_eps(s.comm_start[0], r[0], 1e-7, 1e-7));
    }

    #[test]
    fn two_sources_beat_one() {
        // Same processors; adding a second source reduces T_f.
        let one = SystemSpec::builder()
            .source(0.5, 0.0)
            .processors(&[1.0, 1.5, 2.0, 2.5])
            .job(100.0)
            .build()
            .unwrap();
        let two = SystemSpec::builder()
            .source(0.5, 0.0)
            .source(0.5, 0.0)
            .processors(&[1.0, 1.5, 2.0, 2.5])
            .job(100.0)
            .build()
            .unwrap();
        let s1 = solve(&one).unwrap();
        let s2 = solve(&two).unwrap();
        assert!(s2.makespan < s1.makespan, "{} !< {}", s2.makespan, s1.makespan);
    }

    #[test]
    fn fe_at_least_as_fast_as_nfe() {
        // Front-ends overlap compute with comm, so the FE optimum can
        // only be <= the NFE optimum on the same spec.
        let spec = table2_spec();
        let nfe = solve(&spec).unwrap();
        let fe =
            crate::pipeline::solve(&crate::dlt::frontend::FeOptions::default(), &spec).unwrap();
        assert!(fe.makespan <= nfe.makespan + 1e-6, "fe {} > nfe {}", fe.makespan, nfe.makespan);
    }

    #[test]
    fn src_busy_constraint_can_bind() {
        // Dropping eq. 12 can only help (or tie) the makespan.
        let spec = table2_spec();
        let with = solve_opts(&spec, &NfeOptions::default()).unwrap();
        let without = solve_opts(
            &spec,
            &NfeOptions { drop_source_busy_constraint: true },
        )
        .unwrap();
        assert!(without.makespan <= with.makespan + 1e-7);
    }

    #[test]
    fn m1_n3_edge_case() {
        let spec = SystemSpec::builder()
            .source(0.1, 0.0)
            .source(0.2, 0.1)
            .source(0.3, 0.2)
            .processors(&[1.0])
            .job(12.0)
            .build()
            .unwrap();
        let s = solve(&spec).unwrap();
        assert!(approx_eq_eps(s.total_load(), 12.0, 1e-7, 1e-7));
    }
}
