//! The paper's scheduling formulations.
//!
//! - [`single_source`] — §2: classic one-source DLT with the recursive
//!   closed-form solution (also solved via a dense linear system as a
//!   cross-check oracle).
//! - [`frontend`] — §3.1: multi-source LP for processors *with*
//!   front-end co-processors (receive and compute simultaneously).
//! - [`no_frontend`] — §3.2: multi-source LP for processors *without*
//!   front-ends (compute only after all data arrived), with explicit
//!   per-fraction transmission windows `TS_{i,j}` / `TF_{i,j}`.
//! - [`schedule`] — the unified [`schedule::Schedule`] produced by all
//!   solvers: load fractions, communication windows, compute windows,
//!   makespan.
//! - [`validate`] — post-hoc validation of a schedule against the
//!   paper's timing semantics (independent of the LP).

pub mod concurrent;
pub mod frontend;
pub mod multi_job;
pub mod no_frontend;
pub mod schedule;
pub mod single_source;
pub mod validate;

pub use schedule::Schedule;
pub use validate::{validate, ValidationReport};
