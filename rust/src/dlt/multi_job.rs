//! Extension (paper §8, "multiple jobs arrive at the processing
//! nodes"): a FIFO multi-job pipeline on the front-end system.
//!
//! Jobs arrive over time and are scheduled one at a time with the §3.1
//! LP, but the *system state* carries over between jobs:
//!
//! - a source cannot start distributing job `k+1` before it finished
//!   distributing job `k` (its effective release time moves), and
//! - a front-end processor can *receive* job `k+1` while still
//!   computing job `k`, but cannot start computing it earlier than its
//!   previous compute finishes (the LP's `proc_ready` extension).
//!
//! This pipelines communication under compute — precisely what
//! front-ends are for — and yields throughput well above one-job-at-
//! a-time serialization.

use crate::dlt::frontend::{self, FeOptions};
use crate::dlt::Schedule;
use crate::error::Result;
use crate::lp::{LpProblem, LpSolution, WarmCache};
use crate::model::SystemSpec;
use crate::pipeline::{self, ScenarioModel};

/// The multi-job scenario family: one FIFO pipeline *step* — the §3.1
/// LP with the carried-over per-processor `proc_ready` state. Each job
/// in [`schedule_fifo`] is one instance of this model; consecutive jobs
/// share LP shapes, so a [`WarmCache`] threads their optimal bases
/// through the whole arrival stream.
#[derive(Debug, Clone, Default)]
pub struct MultiJobStepModel {
    /// The underlying §3.1 options (carrying `proc_ready`).
    pub fe: FeOptions,
}

impl ScenarioModel for MultiJobStepModel {
    fn name(&self) -> &'static str {
        "multi_job"
    }

    fn build_lp(&self, spec: &SystemSpec) -> LpProblem {
        frontend::build_lp(spec, &self.fe)
    }

    fn schedule(&self, spec: &SystemSpec, sol: &LpSolution) -> Result<Schedule> {
        frontend::schedule_from_solution(spec, sol)
    }
}

/// One job in the arrival stream.
#[derive(Debug, Clone, Copy)]
pub struct Job {
    /// Arrival time (absolute).
    pub arrival: f64,
    /// Job size (same units as `SystemSpec::job`).
    pub size: f64,
}

/// Scheduling record for one job.
#[derive(Debug, Clone)]
pub struct JobRecord {
    /// Index in arrival order.
    pub index: usize,
    /// The job.
    pub job: Job,
    /// Time the job finished processing (absolute).
    pub finish: f64,
    /// Sojourn time (`finish − arrival`).
    pub sojourn: f64,
    /// The per-job schedule (times are absolute).
    pub schedule: Schedule,
}

/// Pipeline outcome.
#[derive(Debug, Clone)]
pub struct PipelineReport {
    /// Per-job records, in arrival order.
    pub records: Vec<JobRecord>,
    /// Completion time of the last job.
    pub makespan: f64,
    /// Jobs per unit time over the whole horizon.
    pub throughput: f64,
    /// Mean sojourn time.
    pub mean_sojourn: f64,
    /// What a serial (no-pipelining) execution would have taken.
    pub serial_makespan: f64,
}

/// Schedule a FIFO stream of jobs on `spec`'s nodes (front-end model).
///
/// `spec.job` is ignored; each [`Job::size`] is used instead.
pub fn schedule_fifo(spec: &SystemSpec, jobs: &[Job]) -> Result<PipelineReport> {
    assert!(!jobs.is_empty(), "no jobs");
    let n = spec.n();
    let m = spec.m();
    // Mutable node state: when each source is free again, and when
    // each processor finishes its current compute.
    let mut src_free = spec.releases();
    let mut proc_ready = vec![0.0f64; m];

    let mut records = Vec::with_capacity(jobs.len());
    let mut serial_clock = 0.0f64;
    // One warm cache across the stream: steady-state jobs share an LP
    // shape, so each solve seeds from the previous job's basis.
    let mut cache = WarmCache::new();

    for (index, &job) in jobs.iter().enumerate() {
        // Source release for this job: max(arrival, source free).
        let releases: Vec<f64> = src_free.iter().map(|&f| f.max(job.arrival)).collect();
        // Times in the per-job LP are absolute (releases already are).
        let mut sub = spec.clone();
        for (s, &r) in sub.sources.iter_mut().zip(releases.iter()) {
            s.release = r;
        }
        sub.job = job.size;
        // Re-sorting is unnecessary: G order is unchanged; but release
        // order may now violate nothing (releases are free-form).
        let step = MultiJobStepModel {
            fe: FeOptions { proc_ready: Some(proc_ready.clone()), ..Default::default() },
        };
        let sched = pipeline::solve_cached(&step, &sub, &mut cache)?;

        // Advance node state from the timed schedule.
        for i in 0..n {
            src_free[i] = sched.comm_end[i * m + m - 1].max(src_free[i]);
        }
        for j in 0..m {
            // Next job's compute can begin once this job's compute is
            // done on j (receive may overlap — front-end).
            let busy: f64 =
                (0..n).map(|i| sched.beta[i * m + j]).sum::<f64>() * spec.processors[j].a;
            let start = sched.compute_start[j].max(proc_ready[j]);
            proc_ready[j] = if busy > 0.0 { start + busy } else { proc_ready[j] };
        }
        let finish = proc_ready
            .iter()
            .cloned()
            .fold(sched.makespan, f64::max)
            .max(sched.makespan);

        // Serial baseline: wait for everything, then run alone.
        let mut serial_spec = spec.clone();
        let base_release = spec.releases();
        let serial_start = serial_clock.max(job.arrival);
        for (s, &r) in serial_spec.sources.iter_mut().zip(base_release.iter()) {
            s.release = serial_start + r;
        }
        serial_spec.job = job.size;
        let serial = pipeline::solve(&FeOptions::default(), &serial_spec)?;
        serial_clock = serial.makespan;

        records.push(JobRecord {
            index,
            job,
            finish,
            sojourn: finish - job.arrival,
            schedule: sched,
        });
    }

    let makespan = records.iter().map(|r| r.finish).fold(0.0, f64::max);
    let first_arrival = jobs.iter().map(|j| j.arrival).fold(f64::INFINITY, f64::min);
    let horizon = (makespan - first_arrival).max(1e-12);
    let mean_sojourn = records.iter().map(|r| r.sojourn).sum::<f64>() / records.len() as f64;
    Ok(PipelineReport {
        makespan,
        throughput: jobs.len() as f64 / horizon,
        mean_sojourn,
        serial_makespan: serial_clock,
        records,
    })
}

/// Generate a deterministic Poisson-ish arrival stream for benches and
/// examples (exponential gaps, fixed seed).
pub fn synth_jobs(count: usize, mean_gap: f64, size: f64, seed: u64) -> Vec<Job> {
    use crate::util::rng::{Pcg32, Rng};
    let mut rng = Pcg32::new(seed);
    let mut t = 0.0;
    (0..count)
        .map(|_| {
            let gap = -mean_gap * (1.0 - rng.f64()).ln();
            t += gap;
            Job { arrival: t, size }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fe_solve(spec: &SystemSpec) -> Result<Schedule> {
        pipeline::solve(&FeOptions::default(), spec)
    }

    fn spec() -> SystemSpec {
        SystemSpec::builder()
            .source(0.1, 0.0)
            .source(0.15, 1.0)
            .processors(&[1.0, 1.5, 2.0, 2.5])
            .job(1.0) // overridden per job
            .build()
            .unwrap()
    }

    #[test]
    fn single_job_matches_plain_solve() {
        let s = spec();
        let jobs = [Job { arrival: 0.0, size: 50.0 }];
        let rep = schedule_fifo(&s, &jobs).unwrap();
        let plain = fe_solve(&s.with_job(50.0)).unwrap();
        assert!((rep.makespan - plain.makespan).abs() < 1e-6);
        assert_eq!(rep.records.len(), 1);
    }

    #[test]
    fn pipelining_beats_serial() {
        let s = spec();
        let jobs: Vec<Job> =
            (0..5).map(|k| Job { arrival: 2.0 * k as f64, size: 40.0 }).collect();
        let rep = schedule_fifo(&s, &jobs).unwrap();
        assert!(
            rep.makespan < rep.serial_makespan - 1e-6,
            "pipeline {} !< serial {}",
            rep.makespan,
            rep.serial_makespan
        );
    }

    #[test]
    fn fifo_completion_order_and_state_monotone() {
        let s = spec();
        let jobs = synth_jobs(6, 3.0, 30.0, 7);
        let rep = schedule_fifo(&s, &jobs).unwrap();
        for w in rep.records.windows(2) {
            // FIFO on a shared pipeline: finishes are non-decreasing.
            assert!(w[1].finish >= w[0].finish - 1e-9);
        }
        for r in &rep.records {
            assert!(r.sojourn > 0.0);
            assert!((r.schedule.total_load() - r.job.size).abs() < 1e-6);
        }
    }

    #[test]
    fn sparse_arrivals_do_not_interfere() {
        // Jobs far apart: each should finish like a lone job.
        let s = spec();
        let lone = fe_solve(&s.with_job(20.0)).unwrap().makespan;
        let gap = 10.0 * lone;
        let jobs: Vec<Job> =
            (0..3).map(|k| Job { arrival: gap * k as f64, size: 20.0 }).collect();
        let rep = schedule_fifo(&s, &jobs).unwrap();
        for r in &rep.records {
            // Sojourn ~ lone makespan relative to its own start
            // (releases R_i ≥ arrival shift the whole schedule).
            assert!(
                r.sojourn <= lone + 1.5,
                "job {} sojourn {} vs lone {lone}",
                r.index,
                r.sojourn
            );
        }
    }

    #[test]
    fn synth_jobs_deterministic_and_ordered() {
        let a = synth_jobs(10, 2.0, 5.0, 42);
        let b = synth_jobs(10, 2.0, 5.0, 42);
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(b.iter()) {
            assert_eq!(x.arrival, y.arrival);
        }
        assert!(a.windows(2).all(|w| w[0].arrival <= w[1].arrival));
    }

    #[test]
    fn throughput_reported() {
        let s = spec();
        let rep = schedule_fifo(&s, &synth_jobs(4, 5.0, 25.0, 3)).unwrap();
        assert!(rep.throughput > 0.0);
        assert!(rep.mean_sojourn > 0.0);
    }
}
