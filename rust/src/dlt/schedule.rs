//! The unified schedule produced by every solver in [`crate::dlt`].

/// Which timing model produced a schedule.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TimingModel {
    /// §3.1 — processors have front-ends (compute while receiving).
    FrontEnd,
    /// §3.2 / §2 — processors compute only after receiving everything.
    NoFrontEnd,
}

/// A fully-timed load-distribution schedule for an `N × M` system.
///
/// All matrices are row-major `N × M` flattened: entry `(i, j)` is
/// source `i` → processor `j`.
#[derive(Debug, Clone)]
pub struct Schedule {
    /// Number of sources.
    pub n: usize,
    /// Number of processors.
    pub m: usize,
    /// Timing model that produced the schedule.
    pub model: TimingModel,
    /// Load fractions `β_{i,j}` (absolute load units; sums to `J`).
    pub beta: Vec<f64>,
    /// Communication window start `TS_{i,j}`.
    pub comm_start: Vec<f64>,
    /// Communication window end `TF_{i,j}`.
    pub comm_end: Vec<f64>,
    /// Per-processor compute start.
    pub compute_start: Vec<f64>,
    /// Per-processor compute end.
    pub compute_end: Vec<f64>,
    /// The LP's optimal finish time `T_f`.
    pub makespan: f64,
    /// Simplex iterations used to solve the LP (0 for closed form).
    pub lp_iterations: usize,
}

impl Schedule {
    /// `β_{i,j}`.
    pub fn beta(&self, i: usize, j: usize) -> f64 {
        self.beta[i * self.m + j]
    }

    /// Total load processed by processor `j`: `Σ_i β_{i,j}`.
    pub fn load_on_processor(&self, j: usize) -> f64 {
        (0..self.n).map(|i| self.beta(i, j)).sum()
    }

    /// Total load distributed by source `i`: `α_i = Σ_j β_{i,j}`.
    pub fn load_from_source(&self, i: usize) -> f64 {
        (0..self.m).map(|j| self.beta(i, j)).sum()
    }

    /// Sum of all fractions (should equal `J`).
    pub fn total_load(&self) -> f64 {
        self.beta.iter().sum()
    }

    /// Compute busy time of processor `j` given its `A_j`.
    pub fn busy_time(&self, j: usize, a_j: f64) -> f64 {
        self.load_on_processor(j) * a_j
    }

    /// Utilization of processor `j` relative to the makespan.
    pub fn utilization(&self, j: usize, a_j: f64) -> f64 {
        if self.makespan <= 0.0 {
            0.0
        } else {
            self.busy_time(j, a_j) / self.makespan
        }
    }

    /// Realized makespan from the timed windows (`max` compute end);
    /// equal to [`Schedule::makespan`] for tight LP solutions.
    pub fn realized_makespan(&self) -> f64 {
        self.compute_end.iter().fold(0.0f64, |acc, &x| acc.max(x))
    }

    /// Communication gap on source `i` between consecutive fractions
    /// `j` and `j+1` (time the link sits idle).
    pub fn source_gap(&self, i: usize, j: usize) -> f64 {
        debug_assert!(j + 1 < self.m);
        self.comm_start[i * self.m + j + 1] - self.comm_end[i * self.m + j]
    }

    /// Sum of idle-link time across all sources.
    pub fn total_source_idle(&self) -> f64 {
        let mut idle = 0.0;
        for i in 0..self.n {
            for j in 0..self.m.saturating_sub(1) {
                idle += self.source_gap(i, j).max(0.0);
            }
        }
        idle
    }

    /// Render a compact text table of the fractions (for CLI output).
    pub fn render_beta_table(&self) -> String {
        let mut out = String::new();
        out.push_str("       ");
        for j in 0..self.m {
            out.push_str(&format!("{:>10}", format!("P{}", j + 1)));
        }
        out.push_str(&format!("{:>10}\n", "alpha_i"));
        for i in 0..self.n {
            out.push_str(&format!("S{:<6}", i + 1));
            for j in 0..self.m {
                out.push_str(&format!("{:>10.4}", self.beta(i, j)));
            }
            out.push_str(&format!("{:>10.4}\n", self.load_from_source(i)));
        }
        out.push_str("sum    ");
        for j in 0..self.m {
            out.push_str(&format!("{:>10.4}", self.load_on_processor(j)));
        }
        out.push_str(&format!("{:>10.4}\n", self.total_load()));
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy() -> Schedule {
        // 2x2, trivially timed.
        Schedule {
            n: 2,
            m: 2,
            model: TimingModel::NoFrontEnd,
            beta: vec![1.0, 2.0, 3.0, 4.0],
            comm_start: vec![0.0, 1.0, 1.0, 3.0],
            comm_end: vec![1.0, 3.0, 3.0, 5.0],
            compute_start: vec![3.0, 5.0],
            compute_end: vec![7.0, 11.0],
            makespan: 11.0,
            lp_iterations: 0,
        }
    }

    #[test]
    fn accessors() {
        let s = toy();
        assert_eq!(s.beta(0, 1), 2.0);
        assert_eq!(s.load_on_processor(0), 4.0);
        assert_eq!(s.load_from_source(1), 7.0);
        assert_eq!(s.total_load(), 10.0);
        assert_eq!(s.realized_makespan(), 11.0);
    }

    #[test]
    fn utilization_and_busy() {
        let s = toy();
        assert_eq!(s.busy_time(0, 2.0), 8.0);
        assert!((s.utilization(0, 2.0) - 8.0 / 11.0).abs() < 1e-12);
    }

    #[test]
    fn gaps() {
        let s = toy();
        assert_eq!(s.source_gap(0, 0), 0.0);
        assert_eq!(s.source_gap(1, 0), 0.0);
        assert_eq!(s.total_source_idle(), 0.0);
    }

    #[test]
    fn table_renders() {
        let s = toy();
        let t = s.render_beta_table();
        assert!(t.contains("P1"));
        assert!(t.contains("alpha_i"));
    }
}
