//! System specification: sources, processors, and the job.
//!
//! Mirrors the paper's notation: source `S_i` has inverse link speed
//! `G_i` and release time `R_i`; processor `P_j` has inverse compute
//! speed `A_j` and price `C_j` per unit busy time; the job has total
//! size `J`.

pub mod spec;

pub use spec::{Processor, Source, SpecBuilder, SystemSpec};
