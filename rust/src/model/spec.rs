//! `SystemSpec` and its builder / validation / sorting.

use crate::error::{Error, Result};

/// A load source `S_i`.
#[derive(Debug, Clone, PartialEq)]
pub struct Source {
    /// Inverse communication speed `G_i` (time per unit load).
    pub g: f64,
    /// Release time `R_i` (when the source first becomes available).
    pub release: f64,
    /// Display name.
    pub name: String,
}

/// A processing node `P_j`.
#[derive(Debug, Clone, PartialEq)]
pub struct Processor {
    /// Inverse computation speed `A_j` (time per unit load).
    pub a: f64,
    /// Monetary cost `C_j` per unit of busy time (0 when unused).
    pub cost_rate: f64,
    /// Display name.
    pub name: String,
}

/// Full system description for one scheduling instance.
#[derive(Debug, Clone, PartialEq)]
pub struct SystemSpec {
    /// Sources, expected sorted by ascending `G_i` (paper §3: the
    /// fastest links distribute first).
    pub sources: Vec<Source>,
    /// Processors, expected sorted by ascending `A_j` (paper §2: the
    /// fastest processors receive load first).
    pub processors: Vec<Processor>,
    /// Total job size `J`.
    pub job: f64,
}

impl SystemSpec {
    /// Start building a spec.
    pub fn builder() -> SpecBuilder {
        SpecBuilder::default()
    }

    /// Number of sources `N`.
    pub fn n(&self) -> usize {
        self.sources.len()
    }

    /// Number of processors `M`.
    pub fn m(&self) -> usize {
        self.processors.len()
    }

    /// `G_i` as a vector.
    pub fn g(&self) -> Vec<f64> {
        self.sources.iter().map(|s| s.g).collect()
    }

    /// `R_i` as a vector.
    pub fn releases(&self) -> Vec<f64> {
        self.sources.iter().map(|s| s.release).collect()
    }

    /// `A_j` as a vector.
    pub fn a(&self) -> Vec<f64> {
        self.processors.iter().map(|p| p.a).collect()
    }

    /// `C_j` as a vector.
    pub fn cost_rates(&self) -> Vec<f64> {
        self.processors.iter().map(|p| p.cost_rate).collect()
    }

    /// Validate physical sanity and the paper's ordering conventions.
    pub fn validate(&self) -> Result<()> {
        if self.sources.is_empty() {
            return Err(Error::InvalidSpec("no sources".into()));
        }
        if self.processors.is_empty() {
            return Err(Error::InvalidSpec("no processors".into()));
        }
        if !(self.job > 0.0) {
            return Err(Error::InvalidSpec(format!("job size must be > 0, got {}", self.job)));
        }
        for (i, s) in self.sources.iter().enumerate() {
            if !(s.g > 0.0) || !s.g.is_finite() {
                return Err(Error::InvalidSpec(format!("source {i}: G = {} must be > 0", s.g)));
            }
            if s.release < 0.0 || !s.release.is_finite() {
                return Err(Error::InvalidSpec(format!(
                    "source {i}: release = {} must be >= 0",
                    s.release
                )));
            }
        }
        for (j, p) in self.processors.iter().enumerate() {
            if !(p.a > 0.0) || !p.a.is_finite() {
                return Err(Error::InvalidSpec(format!("processor {j}: A = {} must be > 0", p.a)));
            }
            if p.cost_rate < 0.0 {
                return Err(Error::InvalidSpec(format!(
                    "processor {j}: cost rate {} must be >= 0",
                    p.cost_rate
                )));
            }
        }
        for w in self.sources.windows(2) {
            if w[0].g > w[1].g + 1e-12 {
                return Err(Error::InvalidSpec(
                    "sources must be sorted by ascending G (use sorted())".into(),
                ));
            }
        }
        for w in self.processors.windows(2) {
            if w[0].a > w[1].a + 1e-12 {
                return Err(Error::InvalidSpec(
                    "processors must be sorted by ascending A (use sorted())".into(),
                ));
            }
        }
        Ok(())
    }

    /// Return a copy sorted into the paper's canonical order
    /// (sources by ascending `G`, processors by ascending `A`), plus
    /// the permutations mapping sorted index -> original index.
    pub fn sorted(&self) -> (SystemSpec, Vec<usize>, Vec<usize>) {
        let mut src_idx: Vec<usize> = (0..self.sources.len()).collect();
        src_idx.sort_by(|&x, &y| self.sources[x].g.partial_cmp(&self.sources[y].g).unwrap());
        let mut proc_idx: Vec<usize> = (0..self.processors.len()).collect();
        proc_idx.sort_by(|&x, &y| self.processors[x].a.partial_cmp(&self.processors[y].a).unwrap());
        let spec = SystemSpec {
            sources: src_idx.iter().map(|&i| self.sources[i].clone()).collect(),
            processors: proc_idx.iter().map(|&j| self.processors[j].clone()).collect(),
            job: self.job,
        };
        (spec, src_idx, proc_idx)
    }

    /// Restrict to the first `m` processors (they are the fastest when
    /// sorted) — used by every "vs number of processors" sweep.
    pub fn with_m_processors(&self, m: usize) -> SystemSpec {
        assert!(m >= 1 && m <= self.processors.len());
        SystemSpec {
            sources: self.sources.clone(),
            processors: self.processors[..m].to_vec(),
            job: self.job,
        }
    }

    /// Restrict to the first `n` sources.
    pub fn with_n_sources(&self, n: usize) -> SystemSpec {
        assert!(n >= 1 && n <= self.sources.len());
        SystemSpec {
            sources: self.sources[..n].to_vec(),
            processors: self.processors.clone(),
            job: self.job,
        }
    }

    /// Copy with a different job size.
    pub fn with_job(&self, job: f64) -> SystemSpec {
        SystemSpec { job, ..self.clone() }
    }

    /// Copy with all release times scaled by `s >= 0` (the sweep
    /// engine's release-time axis; `s = 0` makes every source available
    /// immediately). Source order is unaffected — releases play no role
    /// in the sort.
    pub fn with_scaled_releases(&self, s: f64) -> SystemSpec {
        assert!(s >= 0.0 && s.is_finite(), "release scale must be >= 0, got {s}");
        let mut out = self.clone();
        for src in out.sources.iter_mut() {
            src.release *= s;
        }
        out
    }

    /// Copy with all inverse link speeds `G_i` scaled by `s > 0` (the
    /// sweep engine's link-speed axis; `s < 1` means faster links).
    /// Uniform scaling preserves the ascending-`G` sort order.
    pub fn with_scaled_links(&self, s: f64) -> SystemSpec {
        assert!(s > 0.0 && s.is_finite(), "link scale must be > 0, got {s}");
        let mut out = self.clone();
        for src in out.sources.iter_mut() {
            src.g *= s;
        }
        out
    }
}

/// Fluent builder for [`SystemSpec`].
#[derive(Debug, Default, Clone)]
pub struct SpecBuilder {
    sources: Vec<Source>,
    processors: Vec<Processor>,
    job: f64,
}

impl SpecBuilder {
    /// Add a source with inverse link speed `g` and release time.
    pub fn source(mut self, g: f64, release: f64) -> Self {
        let name = format!("S{}", self.sources.len() + 1);
        self.sources.push(Source { g, release, name });
        self
    }

    /// Add several sources with the same release time 0.
    pub fn sources_g(mut self, gs: &[f64]) -> Self {
        for &g in gs {
            self = self.source(g, 0.0);
        }
        self
    }

    /// Add a processor with inverse compute speed `a` (free of charge).
    pub fn processor(self, a: f64) -> Self {
        self.processor_with_cost(a, 0.0)
    }

    /// Add a processor with inverse compute speed `a` and price.
    pub fn processor_with_cost(mut self, a: f64, cost_rate: f64) -> Self {
        let name = format!("P{}", self.processors.len() + 1);
        self.processors.push(Processor { a, cost_rate, name });
        self
    }

    /// Add several processors from their `A_j` values.
    pub fn processors(mut self, a: &[f64]) -> Self {
        for &ai in a {
            self = self.processor(ai);
        }
        self
    }

    /// Add several priced processors from `(A_j, C_j)` pairs.
    pub fn priced_processors(mut self, ac: &[(f64, f64)]) -> Self {
        for &(a, c) in ac {
            self = self.processor_with_cost(a, c);
        }
        self
    }

    /// Set the job size `J`.
    pub fn job(mut self, j: f64) -> Self {
        self.job = j;
        self
    }

    /// Finish, validating the result.
    pub fn build(self) -> Result<SystemSpec> {
        let spec = SystemSpec { sources: self.sources, processors: self.processors, job: self.job };
        spec.validate()?;
        Ok(spec)
    }

    /// Finish without the sorted-order checks (callers that intend to
    /// call `sorted()` themselves).
    pub fn build_unsorted(self) -> Result<SystemSpec> {
        let spec = SystemSpec { sources: self.sources, processors: self.processors, job: self.job };
        let (sorted, _, _) = spec.sorted();
        sorted.validate()?;
        Ok(spec)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn table1_spec() -> SystemSpec {
        SystemSpec::builder()
            .source(0.2, 10.0)
            .source(0.4, 50.0)
            .processors(&[2.0, 3.0, 4.0, 5.0, 6.0])
            .job(100.0)
            .build()
            .unwrap()
    }

    #[test]
    fn builder_produces_paper_table1() {
        let spec = table1_spec();
        assert_eq!(spec.n(), 2);
        assert_eq!(spec.m(), 5);
        assert_eq!(spec.g(), vec![0.2, 0.4]);
        assert_eq!(spec.releases(), vec![10.0, 50.0]);
        assert_eq!(spec.a(), vec![2.0, 3.0, 4.0, 5.0, 6.0]);
        assert_eq!(spec.job, 100.0);
    }

    #[test]
    fn validation_rejects_bad_specs() {
        assert!(SystemSpec::builder().job(1.0).build().is_err()); // no nodes
        assert!(SystemSpec::builder().source(0.1, 0.0).job(1.0).build().is_err()); // no procs
        assert!(SystemSpec::builder()
            .source(0.1, 0.0)
            .processor(1.0)
            .job(0.0)
            .build()
            .is_err()); // zero job
        assert!(SystemSpec::builder()
            .source(-0.1, 0.0)
            .processor(1.0)
            .job(1.0)
            .build()
            .is_err()); // negative G
        assert!(SystemSpec::builder()
            .source(0.1, -1.0)
            .processor(1.0)
            .job(1.0)
            .build()
            .is_err()); // negative release
    }

    #[test]
    fn validation_enforces_sorting() {
        let r = SystemSpec::builder()
            .source(0.4, 0.0)
            .source(0.2, 0.0)
            .processor(1.0)
            .job(1.0)
            .build();
        assert!(r.is_err());
        let r = SystemSpec::builder()
            .source(0.2, 0.0)
            .processors(&[3.0, 2.0])
            .job(1.0)
            .build();
        assert!(r.is_err());
    }

    #[test]
    fn sorted_returns_permutations() {
        let spec = SystemSpec {
            sources: vec![
                Source { g: 0.4, release: 1.0, name: "a".into() },
                Source { g: 0.2, release: 2.0, name: "b".into() },
            ],
            processors: vec![
                Processor { a: 3.0, cost_rate: 0.0, name: "x".into() },
                Processor { a: 2.0, cost_rate: 0.0, name: "y".into() },
            ],
            job: 10.0,
        };
        let (sorted, src_perm, proc_perm) = spec.sorted();
        assert_eq!(sorted.g(), vec![0.2, 0.4]);
        assert_eq!(sorted.a(), vec![2.0, 3.0]);
        assert_eq!(src_perm, vec![1, 0]);
        assert_eq!(proc_perm, vec![1, 0]);
        assert!(sorted.validate().is_ok());
    }

    #[test]
    fn with_m_processors_takes_prefix() {
        let spec = table1_spec();
        let s3 = spec.with_m_processors(3);
        assert_eq!(s3.a(), vec![2.0, 3.0, 4.0]);
        assert_eq!(s3.n(), 2);
    }

    #[test]
    fn with_n_sources_takes_prefix() {
        let spec = table1_spec();
        let s1 = spec.with_n_sources(1);
        assert_eq!(s1.g(), vec![0.2]);
        assert_eq!(s1.m(), 5);
    }

    #[test]
    fn scaling_helpers() {
        let spec = table1_spec();
        let r2 = spec.with_scaled_releases(2.0);
        assert_eq!(r2.releases(), vec![20.0, 100.0]);
        let r0 = spec.with_scaled_releases(0.0);
        assert_eq!(r0.releases(), vec![0.0, 0.0]);
        assert!(r0.validate().is_ok());
        let g05 = spec.with_scaled_links(0.5);
        assert_eq!(g05.g(), vec![0.1, 0.2]);
        assert!(g05.validate().is_ok());
    }
}
