//! Event queue for the discrete-event engine.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

/// Events the engine processes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EventKind {
    /// Source `i` finished transmitting fraction `(i, j)`.
    SendComplete { source: usize, processor: usize },
    /// Processor `j` finished computing everything assigned to it.
    ComputeComplete { processor: usize },
}

/// A timestamped event.
#[derive(Debug, Clone, Copy)]
pub struct Event {
    /// Simulation time.
    pub time: f64,
    /// Tie-break sequence number (FIFO among equal times).
    pub seq: u64,
    /// Payload.
    pub kind: EventKind,
}

impl PartialEq for Event {
    fn eq(&self, other: &Self) -> bool {
        self.time == other.time && self.seq == other.seq
    }
}
impl Eq for Event {}

impl PartialOrd for Event {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for Event {
    fn cmp(&self, other: &Self) -> Ordering {
        // Min-heap: earlier time first; FIFO on ties. BinaryHeap is a
        // max-heap, so reverse.
        other
            .time
            .partial_cmp(&self.time)
            .expect("NaN event time")
            .then_with(|| other.seq.cmp(&self.seq))
    }
}

/// Monotonic event queue.
#[derive(Debug, Default)]
pub struct EventQueue {
    heap: BinaryHeap<Event>,
    next_seq: u64,
    /// Total events ever pushed (for engine metrics).
    pub pushed: u64,
}

impl EventQueue {
    /// Empty queue.
    pub fn new() -> EventQueue {
        EventQueue::default()
    }

    /// Schedule an event.
    pub fn push(&mut self, time: f64, kind: EventKind) {
        debug_assert!(time.is_finite(), "non-finite event time");
        self.heap.push(Event { time, seq: self.next_seq, kind });
        self.next_seq += 1;
        self.pushed += 1;
    }

    /// Pop the earliest event.
    pub fn pop(&mut self) -> Option<Event> {
        self.heap.pop()
    }

    /// Number of pending events.
    pub fn len(&self) -> usize {
        self.heap.len()
    }

    /// True when no events remain.
    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn orders_by_time() {
        let mut q = EventQueue::new();
        q.push(3.0, EventKind::ComputeComplete { processor: 0 });
        q.push(1.0, EventKind::SendComplete { source: 0, processor: 0 });
        q.push(2.0, EventKind::SendComplete { source: 0, processor: 1 });
        assert_eq!(q.pop().unwrap().time, 1.0);
        assert_eq!(q.pop().unwrap().time, 2.0);
        assert_eq!(q.pop().unwrap().time, 3.0);
        assert!(q.pop().is_none());
    }

    #[test]
    fn fifo_on_ties() {
        let mut q = EventQueue::new();
        q.push(1.0, EventKind::SendComplete { source: 0, processor: 0 });
        q.push(1.0, EventKind::SendComplete { source: 1, processor: 1 });
        match q.pop().unwrap().kind {
            EventKind::SendComplete { source, .. } => assert_eq!(source, 0),
            other => unreachable!(
                "FIFO tie-break should pop the first SendComplete pushed, got {other:?}"
            ),
        }
        match q.pop().unwrap().kind {
            EventKind::SendComplete { source, .. } => assert_eq!(source, 1),
            other => unreachable!(
                "FIFO tie-break should pop the second SendComplete pushed, got {other:?}"
            ),
        }
    }

    #[test]
    fn len_tracking() {
        let mut q = EventQueue::new();
        assert!(q.is_empty());
        q.push(1.0, EventKind::ComputeComplete { processor: 0 });
        assert_eq!(q.len(), 1);
        assert_eq!(q.pushed, 1);
    }
}
