//! Execution traces emitted by the simulator.

/// What happened.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TraceKind {
    /// Source began transmitting a fraction.
    SendStart,
    /// Source finished transmitting a fraction.
    SendComplete,
    /// Processor began computing.
    ComputeStart,
    /// Processor finished all its compute.
    ComputeComplete,
    /// Injected fail/restart outage began (processor down, reception
    /// blocked, in-flight compute lost).
    Fail,
    /// Injected fail/restart outage ended (processor back up).
    Restart,
    /// Injected preemption began (compute paused, front-end running).
    PreemptStart,
    /// Injected preemption ended.
    PreemptEnd,
}

/// One trace record.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TraceEvent {
    /// Simulation time.
    pub time: f64,
    /// Event kind.
    pub kind: TraceKind,
    /// Source index (usize::MAX when not applicable).
    pub source: usize,
    /// Processor index.
    pub processor: usize,
}

/// Ordered list of trace records.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Trace {
    /// Records in emission order.
    pub events: Vec<TraceEvent>,
}

impl Trace {
    /// Append a record.
    pub fn push(&mut self, time: f64, kind: TraceKind, source: usize, processor: usize) {
        self.events.push(TraceEvent { time, kind, source, processor });
    }

    /// Verify the trace is time-ordered (within fp wiggle).
    pub fn is_time_ordered(&self) -> bool {
        self.events.windows(2).all(|w| w[0].time <= w[1].time + 1e-9)
    }

    /// Render as a human-readable timeline (for CLI / debugging).
    pub fn render(&self) -> String {
        let mut out = String::new();
        for e in &self.events {
            let who = match e.kind {
                TraceKind::SendStart | TraceKind::SendComplete => {
                    format!("S{} -> P{}", e.source + 1, e.processor + 1)
                }
                _ => format!("P{}", e.processor + 1),
            };
            out.push_str(&format!("{:10.4}  {:16} {}\n", e.time, format!("{:?}", e.kind), who));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ordering_check() {
        let mut t = Trace::default();
        t.push(0.0, TraceKind::SendStart, 0, 0);
        t.push(1.0, TraceKind::SendComplete, 0, 0);
        assert!(t.is_time_ordered());
        t.push(0.5, TraceKind::ComputeStart, usize::MAX, 0);
        assert!(!t.is_time_ordered());
    }

    #[test]
    fn render_contains_nodes() {
        let mut t = Trace::default();
        t.push(0.0, TraceKind::SendStart, 1, 2);
        let s = t.render();
        assert!(s.contains("S2 -> P3"));
    }
}
