//! Deterministic discrete-event simulation.
//!
//! Two engines execute a [`crate::dlt::Schedule`]'s *decisions* (the β
//! matrix and the paper's fixed communication orders) under the
//! operational timing semantics, independently of the LP's own timing
//! variables:
//!
//! - [`engine`] — the original fixed-function ASAP replayer, kept as a
//!   compact parity oracle;
//! - [`cluster`] — the component-based engine ([`cluster::Source`] /
//!   [`cluster::Link`] / [`cluster::Processor`] over a tick queue)
//!   that adds fault/preemption injection, time-varying link capacity,
//!   LP-timeline gating and 10k-processor scale.
//!
//! [`replay`] ties the cluster engine back to the solver pipeline:
//! replay a solved schedule and report predicted-vs-simulated
//! divergence ([`replay::DivergenceReport`], `diagnostics.sim` on the
//! wire). Both engines share [`jitter`] — shape-stable seeded speed
//! perturbations — and the [`trace`] timeline format.

pub mod cluster;
pub mod engine;
pub mod event;
pub mod jitter;
pub mod replay;
pub mod trace;

pub use engine::{simulate, SimOptions, SimResult};
pub use replay::{replay, replay_solved, synthetic_scale, DivergenceReport, Gate, ReplayOptions};
pub use trace::{Trace, TraceEvent, TraceKind};
