//! Deterministic discrete-event simulator.
//!
//! Executes a [`crate::dlt::Schedule`]'s *decisions* (the β matrix and
//! the paper's fixed communication orders) under the operational timing
//! semantics, independently of the LP's own timing variables. The
//! realized makespan from the simulator is the ground truth the LP
//! solutions are checked against.
//!
//! The engine supports multiplicative jitter on link and compute speeds
//! (seeded, deterministic) for robustness experiments: how much does
//! the realized makespan degrade when the real system deviates from
//! the parameters the schedule was optimized for?

pub mod engine;
pub mod timevary;
pub mod event;
pub mod trace;

pub use engine::{simulate, SimOptions, SimResult};
pub use trace::{Trace, TraceEvent, TraceKind};
