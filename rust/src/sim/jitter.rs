//! Shape-stable multiplicative jitter factors.
//!
//! Both simulator engines perturb link and compute times with
//! multiplicative factors drawn uniformly from `[1 − a, 1 + a]`. The
//! draw for a cell must depend only on `(seed, i, j)` — never on the
//! system shape — so that the same `(source, processor)` pair sees the
//! same perturbation whether it lives in a 2×3 or a 2×10 000 system.
//! (The original engine drew factors sequentially from one stream and
//! indexed them by flat position, so adding a processor silently
//! reassigned every later cell's jitter.)
//!
//! Each factor is derived by hashing the indices into an independent
//! [`SplitMix64`] stream: one `next_u64` through the full mix gives a
//! well-distributed 53-bit uniform regardless of how structured the
//! `(seed, i, j)` input is.

use crate::util::rng::{Rng, SplitMix64};

/// Domain-separation tags so link and compute draws never collide even
/// for identical `(seed, index)` inputs.
const TAG_LINK: u64 = 0x6C69_6E6B_6A69_7474; // "linkjitt"
const TAG_COMPUTE: u64 = 0x636F_6D70_6A69_7474; // "compjitt"

/// One uniform draw in `[0, 1)` keyed by `(seed, tag, x, y)`.
fn unit(seed: u64, tag: u64, x: u64, y: u64) -> f64 {
    let key = seed
        ^ tag.rotate_left(17)
        ^ x.wrapping_mul(0x9E37_79B9_7F4A_7C15)
        ^ y.wrapping_mul(0xC2B2_AE3D_27D4_EB4F);
    SplitMix64::new(key).f64()
}

/// Multiplicative factor in `[1 − a, 1 + a]` for a draw in `[0, 1)`.
fn factor(amplitude: f64, u: f64) -> f64 {
    1.0 + amplitude * (2.0 * u - 1.0)
}

/// Link-time factor for fraction `(source i, processor j)`.
/// `amplitude <= 0` disables jitter (returns exactly 1.0).
pub fn link_factor(seed: u64, amplitude: f64, i: usize, j: usize) -> f64 {
    if amplitude <= 0.0 {
        return 1.0;
    }
    factor(amplitude, unit(seed, TAG_LINK, i as u64, j as u64))
}

/// Compute-time factor for processor `j`.
/// `amplitude <= 0` disables jitter (returns exactly 1.0).
pub fn compute_factor(seed: u64, amplitude: f64, j: usize) -> f64 {
    if amplitude <= 0.0 {
        return 1.0;
    }
    factor(amplitude, unit(seed, TAG_COMPUTE, j as u64, 0))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn factors_are_deterministic_and_in_range() {
        for i in 0..8 {
            for j in 0..8 {
                let f1 = link_factor(42, 0.3, i, j);
                let f2 = link_factor(42, 0.3, i, j);
                assert_eq!(f1, f2);
                assert!((0.7..=1.3).contains(&f1), "factor {f1} out of range");
            }
        }
        let c = compute_factor(42, 0.2, 3);
        assert!((0.8..=1.2).contains(&c));
    }

    #[test]
    fn zero_amplitude_is_exactly_nominal() {
        assert_eq!(link_factor(7, 0.0, 1, 2), 1.0);
        assert_eq!(compute_factor(7, 0.0, 1), 1.0);
    }

    #[test]
    fn cells_and_tags_are_independent() {
        // Different cells draw different factors...
        let a = link_factor(1, 0.3, 0, 0);
        let b = link_factor(1, 0.3, 0, 1);
        let c = link_factor(1, 0.3, 1, 0);
        assert!(a != b && a != c && b != c);
        // ...and link vs compute draws never alias on equal indices.
        assert_ne!(link_factor(1, 0.3, 2, 0), compute_factor(1, 0.3, 2));
        // Seeds matter.
        assert_ne!(link_factor(1, 0.3, 0, 0), link_factor(2, 0.3, 0, 0));
    }
}
