//! The discrete-event engine.
//!
//! Inputs: a system spec, a β matrix (the *decisions* of a schedule)
//! and the timing model. The engine re-derives all timing greedily
//! (ASAP under the paper's sequential-communication rules) and reports
//! the realized makespan — an independent check of the LP's `T_f`.

use crate::dlt::schedule::TimingModel;
use crate::model::SystemSpec;
use crate::sim::event::{EventKind, EventQueue};
use crate::sim::jitter;
use crate::sim::trace::{Trace, TraceKind};

/// Simulation options.
#[derive(Debug, Clone)]
pub struct SimOptions {
    /// Timing model to execute under.
    pub model: TimingModel,
    /// Multiplicative jitter amplitude on per-fraction link times
    /// (uniform in `[1−j, 1+j]`). 0 disables.
    pub link_jitter: f64,
    /// Multiplicative jitter amplitude on per-processor compute times.
    pub compute_jitter: f64,
    /// RNG seed for jitter.
    pub seed: u64,
    /// Record a full trace.
    pub trace: bool,
}

impl Default for SimOptions {
    fn default() -> Self {
        SimOptions {
            model: TimingModel::NoFrontEnd,
            link_jitter: 0.0,
            compute_jitter: 0.0,
            seed: 0,
            trace: false,
        }
    }
}

/// Simulation outcome.
#[derive(Debug, Clone)]
pub struct SimResult {
    /// Time the last processor finished computing.
    pub makespan: f64,
    /// Per-processor compute completion times.
    pub compute_done: Vec<f64>,
    /// Per-fraction realized send start times.
    pub send_start: Vec<f64>,
    /// Per-fraction realized send completion times.
    pub send_done: Vec<f64>,
    /// Events processed.
    pub events: u64,
    /// Optional trace.
    pub trace: Option<Trace>,
}

/// Run the simulation for the given β matrix (row-major `N × M`).
pub fn simulate(spec: &SystemSpec, beta: &[f64], opts: &SimOptions) -> SimResult {
    let n = spec.n();
    let m = spec.m();
    assert_eq!(beta.len(), n * m, "beta shape mismatch");
    let g = spec.g();
    let r = spec.releases();
    let a = spec.a();

    // Shape-stable jitter: each cell hashes `(seed, i, j)`, so growing
    // the system never reshuffles the factors of existing cells.
    let link_factor: Vec<f64> = (0..n * m)
        .map(|k| jitter::link_factor(opts.seed, opts.link_jitter, k / m, k % m))
        .collect();
    let compute_factor: Vec<f64> =
        (0..m).map(|j| jitter::compute_factor(opts.seed, opts.compute_jitter, j)).collect();

    let mut q = EventQueue::new();
    let mut trace = if opts.trace { Some(Trace::default()) } else { None };

    // State.
    let mut next_j = vec![0usize; n]; // next fraction each source sends
    let mut src_free_at = r.clone(); // source can't start before release
    let mut proc_next_src = vec![0usize; m]; // next source each proc expects
    let mut proc_recv_free_at = vec![0.0f64; m];
    let mut send_start = vec![0.0f64; n * m];
    let mut send_done = vec![0.0f64; n * m];
    let mut compute_done = vec![0.0f64; m];
    // Front-end streaming state: current end of the compute pipeline.
    let mut fe_compute_end = vec![0.0f64; m];
    let mut fe_started = vec![false; m];

    // Try to start send (i, next_j[i]) if the processor is ready for i.
    // Returns true if the send was scheduled.
    let try_start = |i: usize,
                     q: &mut EventQueue,
                     next_j: &[usize],
                     proc_next_src: &[usize],
                     src_free_at: &[f64],
                     proc_recv_free_at: &[f64],
                     send_start: &mut [f64],
                     trace: &mut Option<Trace>|
     -> bool {
        let j = next_j[i];
        if j >= m {
            return false;
        }
        if proc_next_src[j] != i {
            return false; // processor still expects an earlier source
        }
        let start = src_free_at[i].max(proc_recv_free_at[j]);
        let dur = beta[i * m + j] * g[i] * link_factor[i * m + j];
        send_start[i * m + j] = start;
        if let Some(t) = trace.as_mut() {
            t.push(start, TraceKind::SendStart, i, j);
        }
        q.push(start + dur, EventKind::SendComplete { source: i, processor: j });
        true
    };

    // Seed: every source tries its first send (only sources whose
    // processor expects them will schedule; that's exactly S1 on P1,
    // and later sources block until their predecessor passes).
    let mut sending = vec![false; n];
    for i in 0..n {
        sending[i] = try_start(
            i,
            &mut q,
            &next_j,
            &proc_next_src,
            &src_free_at,
            &proc_recv_free_at,
            &mut send_start,
            &mut trace,
        );
    }

    let mut events = 0u64;
    while let Some(ev) = q.pop() {
        events += 1;
        match ev.kind {
            EventKind::SendComplete { source: i, processor: j } => {
                let t = ev.time;
                send_done[i * m + j] = t;
                if let Some(tr) = trace.as_mut() {
                    tr.push(t, TraceKind::SendComplete, i, j);
                }
                src_free_at[i] = t;
                proc_recv_free_at[j] = t;
                next_j[i] += 1;
                proc_next_src[j] += 1;
                sending[i] = false;

                // Front-end: fraction (i, j) enters the compute pipe.
                if opts.model == TimingModel::FrontEnd {
                    let load = beta[i * m + j];
                    if load > 0.0 {
                        let arrival_began = send_start[i * m + j];
                        if !fe_started[j] {
                            fe_started[j] = true;
                            fe_compute_end[j] = arrival_began;
                            if let Some(tr) = trace.as_mut() {
                                tr.push(arrival_began, TraceKind::ComputeStart, usize::MAX, j);
                            }
                        }
                        // Streaming rule: the pipeline resumes at
                        // max(pipe end, arrival start), burns load*A,
                        // and cannot finish before the data finished
                        // arriving.
                        let resume = fe_compute_end[j].max(arrival_began);
                        fe_compute_end[j] =
                            (resume + load * a[j] * compute_factor[j]).max(t);
                    }
                    if proc_next_src[j] == n {
                        // Last fraction for this processor delivered.
                        compute_done[j] = fe_compute_end[j];
                        q.push(fe_compute_end[j], EventKind::ComputeComplete { processor: j });
                    }
                } else if proc_next_src[j] == n {
                    // No front-end: compute starts now (all data here).
                    let total: f64 = (0..n).map(|s| beta[s * m + j]).sum();
                    let done = t + total * a[j] * compute_factor[j];
                    compute_done[j] = done;
                    if let Some(tr) = trace.as_mut() {
                        tr.push(t, TraceKind::ComputeStart, usize::MAX, j);
                    }
                    q.push(done, EventKind::ComputeComplete { processor: j });
                }

                // Unblock: this source's next send; and the next source
                // waiting on processor j.
                for cand in 0..n {
                    if !sending[cand] && next_j[cand] < m {
                        let started = try_start(
                            cand,
                            &mut q,
                            &next_j,
                            &proc_next_src,
                            &src_free_at,
                            &proc_recv_free_at,
                            &mut send_start,
                            &mut trace,
                        );
                        sending[cand] = started;
                    }
                }
            }
            EventKind::ComputeComplete { processor: j } => {
                if let Some(tr) = trace.as_mut() {
                    tr.push(ev.time, TraceKind::ComputeComplete, usize::MAX, j);
                }
            }
        }
    }

    let makespan = compute_done.iter().fold(0.0f64, |acc, &x| acc.max(x));
    SimResult { makespan, compute_done, send_start, send_done, events, trace }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dlt::frontend::FeOptions;
    use crate::dlt::no_frontend::NfeOptions;
    use crate::dlt::{single_source, Schedule};
    use crate::model::SystemSpec;
    use crate::util::float::approx_eq_eps;

    fn fe_solve(spec: &SystemSpec) -> Schedule {
        crate::pipeline::solve(&FeOptions::default(), spec).unwrap()
    }

    fn nfe_solve(spec: &SystemSpec) -> Schedule {
        crate::pipeline::solve(&NfeOptions::default(), spec).unwrap()
    }

    #[test]
    fn single_source_matches_closed_form() {
        let g = 0.2;
        let a = [2.0, 3.0, 4.0];
        let cf = single_source::solve(g, &a, 100.0, 0.0).unwrap();
        let spec = SystemSpec::builder()
            .source(g, 0.0)
            .processors(&a)
            .job(100.0)
            .build()
            .unwrap();
        let res = simulate(&spec, &cf.beta, &SimOptions::default());
        assert!(
            approx_eq_eps(res.makespan, cf.makespan, 1e-9, 1e-9),
            "sim {} vs cf {}",
            res.makespan,
            cf.makespan
        );
    }

    #[test]
    fn nfe_lp_schedule_is_achievable() {
        let spec = SystemSpec::builder()
            .source(0.2, 0.0)
            .source(0.2, 5.0)
            .processors(&[2.0, 3.0, 4.0])
            .job(100.0)
            .build()
            .unwrap();
        let sched = nfe_solve(&spec);
        let res = simulate(&spec, &sched.beta, &SimOptions::default());
        // ASAP execution can only match or beat the LP's T_f (the LP may
        // stretch windows; ASAP closes gaps).
        assert!(
            res.makespan <= sched.makespan + 1e-6,
            "sim {} > LP {}",
            res.makespan,
            sched.makespan
        );
    }

    #[test]
    fn fe_lp_schedule_is_achievable() {
        let spec = SystemSpec::builder()
            .source(0.2, 10.0)
            .source(0.4, 50.0)
            .processors(&[2.0, 3.0, 4.0, 5.0, 6.0])
            .job(100.0)
            .build()
            .unwrap();
        let sched = fe_solve(&spec);
        let res = simulate(
            &spec,
            &sched.beta,
            &SimOptions { model: crate::dlt::schedule::TimingModel::FrontEnd, ..Default::default() },
        );
        assert!(
            res.makespan <= sched.makespan + 1e-6,
            "sim {} > LP {}",
            res.makespan,
            sched.makespan
        );
    }

    #[test]
    fn trace_is_ordered_and_complete() {
        let spec = SystemSpec::builder()
            .source(0.2, 0.0)
            .source(0.3, 1.0)
            .processors(&[1.0, 2.0])
            .job(10.0)
            .build()
            .unwrap();
        let sched = nfe_solve(&spec);
        let res = simulate(
            &spec,
            &sched.beta,
            &SimOptions { trace: true, ..Default::default() },
        );
        let trace = res.trace.unwrap();
        // 2x2 sends (start+complete) + 2 compute starts + 2 completes.
        assert_eq!(trace.events.len(), 2 * 2 * 2 + 2 + 2);
        let mut sorted = trace.events.clone();
        sorted.sort_by(|x, y| x.time.partial_cmp(&y.time).unwrap());
        // All events present regardless of emission order.
        assert_eq!(sorted.len(), trace.events.len());
    }

    #[test]
    fn jitter_changes_makespan_deterministically() {
        let spec = SystemSpec::builder()
            .source(0.2, 0.0)
            .source(0.2, 1.0)
            .processors(&[2.0, 3.0])
            .job(50.0)
            .build()
            .unwrap();
        let sched = nfe_solve(&spec);
        let base = simulate(&spec, &sched.beta, &SimOptions::default());
        let j1 = simulate(
            &spec,
            &sched.beta,
            &SimOptions { link_jitter: 0.2, compute_jitter: 0.2, seed: 7, ..Default::default() },
        );
        let j2 = simulate(
            &spec,
            &sched.beta,
            &SimOptions { link_jitter: 0.2, compute_jitter: 0.2, seed: 7, ..Default::default() },
        );
        assert_eq!(j1.makespan, j2.makespan, "same seed, same result");
        assert!((j1.makespan - base.makespan).abs() > 1e-9, "jitter had no effect");
    }

    #[test]
    fn jitter_is_shape_stable() {
        // Growing the system must not reshuffle the jitter on existing
        // cells: factors hash (seed, i, j), not a sequential stream.
        let opts = SimOptions {
            link_jitter: 0.3,
            compute_jitter: 0.3,
            seed: 7,
            ..Default::default()
        };
        let spec2 = SystemSpec::builder()
            .source(0.2, 0.0)
            .source(0.3, 0.0)
            .processors(&[2.0, 3.0])
            .job(10.0)
            .build()
            .unwrap();
        let beta2 = vec![3.0, 3.0, 4.0, 0.0];
        let res2 = simulate(&spec2, &beta2, &opts);
        let spec3 = SystemSpec::builder()
            .source(0.2, 0.0)
            .source(0.3, 0.0)
            .processors(&[2.0, 3.0, 4.0])
            .job(10.0)
            .build()
            .unwrap();
        let beta3 = vec![3.0, 2.0, 1.0, 4.0, 0.0, 0.0];
        let res3 = simulate(&spec3, &beta3, &opts);
        // Same cell (S2 -> P1), same load: identical jittered duration
        // even though the flat draw position changed (2 vs 3).
        assert_eq!(
            res2.send_done[2] - res2.send_start[2],
            res3.send_done[3] - res3.send_start[3]
        );
        // Same column total on P1: identical jittered compute burn.
        assert_eq!(
            res2.compute_done[0] - res2.send_done[2],
            res3.compute_done[0] - res3.send_done[3]
        );
    }

    #[test]
    fn sequential_rules_respected_in_sim() {
        let spec = SystemSpec::builder()
            .source(0.2, 0.0)
            .source(0.25, 0.5)
            .source(0.3, 1.0)
            .processors(&[1.0, 1.5, 2.0, 2.5])
            .job(60.0)
            .build()
            .unwrap();
        let sched = nfe_solve(&spec);
        let res = simulate(&spec, &sched.beta, &SimOptions::default());
        let (n, m) = (3, 4);
        for i in 0..n {
            for j in 0..m - 1 {
                assert!(
                    res.send_done[i * m + j] <= res.send_start[i * m + j + 1] + 1e-9,
                    "source {i} overlap"
                );
            }
        }
        for j in 0..m {
            for i in 0..n - 1 {
                assert!(
                    res.send_done[i * m + j] <= res.send_start[(i + 1) * m + j] + 1e-9,
                    "proc {j} overlap"
                );
            }
        }
    }

    #[test]
    fn event_count_is_linear() {
        let spec = SystemSpec::builder()
            .source(0.2, 0.0)
            .processors(&[1.0, 2.0, 3.0])
            .job(10.0)
            .build()
            .unwrap();
        let sched = nfe_solve(&spec);
        let res = simulate(&spec, &sched.beta, &SimOptions::default());
        assert_eq!(res.events, 3 + 3); // 3 sends + 3 computes
    }
}
