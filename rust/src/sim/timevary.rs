//! Extension (paper §8, "processing speed become time-varying"):
//! piecewise-constant speed profiles and schedule re-evaluation.
//!
//! The paper's future work asks what happens when processor speeds
//! (and link speeds) vary over time, e.g. because other jobs are
//! injected. This module models a speed profile as a piecewise-
//! constant *capacity multiplier* `c(t) > 0` (1.0 = nominal): work
//! that nominally takes `w` time units completes when the integral of
//! `c` reaches `w`. [`evaluate`] replays a β-matrix under profiles and
//! reports the realized makespan — quantifying how brittle a schedule
//! optimized for nominal speeds is under load injection.

use crate::dlt::schedule::TimingModel;
use crate::model::SystemSpec;

/// Piecewise-constant capacity multiplier.
#[derive(Debug, Clone)]
pub struct Profile {
    /// Breakpoints: `(start_time, multiplier)`; first entry must start
    /// at 0. Multipliers must be > 0.
    pub pieces: Vec<(f64, f64)>,
}

impl Profile {
    /// Constant nominal capacity.
    pub fn nominal() -> Profile {
        Profile { pieces: vec![(0.0, 1.0)] }
    }

    /// A background job occupies `share` of the node during
    /// `[from, to)` (capacity drops to `1 − share`).
    pub fn with_interference(from: f64, to: f64, share: f64) -> Profile {
        assert!((0.0..1.0).contains(&share), "share in [0,1)");
        assert!(from >= 0.0 && to > from);
        let mut pieces = vec![(0.0, 1.0)];
        if from > 0.0 {
            pieces.push((from, 1.0 - share));
        } else {
            pieces[0].1 = 1.0 - share;
        }
        pieces.push((to, 1.0));
        Profile { pieces }
    }

    /// Validate invariants.
    pub fn check(&self) -> Result<(), String> {
        if self.pieces.is_empty() || self.pieces[0].0 != 0.0 {
            return Err("profile must start at t = 0".into());
        }
        for w in self.pieces.windows(2) {
            if w[1].0 <= w[0].0 {
                return Err("breakpoints must increase".into());
            }
        }
        if self.pieces.iter().any(|&(_, c)| c <= 0.0) {
            return Err("multipliers must be > 0".into());
        }
        Ok(())
    }

    /// Time at which `work` nominal units complete when started at
    /// `start` under this profile.
    pub fn finish_time(&self, start: f64, work: f64) -> f64 {
        debug_assert!(self.check().is_ok());
        if work <= 0.0 {
            return start;
        }
        let mut remaining = work;
        let mut t = start;
        let mut idx = match self.pieces.iter().rposition(|&(s, _)| s <= t) {
            Some(i) => i,
            None => 0,
        };
        loop {
            let (_, cap) = self.pieces[idx];
            let piece_end = self.pieces.get(idx + 1).map(|&(s, _)| s).unwrap_or(f64::INFINITY);
            let span = piece_end - t;
            let doable = span * cap;
            if doable >= remaining {
                return t + remaining / cap;
            }
            remaining -= doable;
            t = piece_end;
            idx += 1;
        }
    }
}

/// Result of replaying a schedule under profiles.
#[derive(Debug, Clone)]
pub struct TimeVaryResult {
    /// Realized makespan.
    pub makespan: f64,
    /// Per-processor completion times.
    pub compute_done: Vec<f64>,
}

/// Replay the β matrix under per-source link profiles and
/// per-processor compute profiles (sequential protocol, ASAP, same
/// semantics as [`crate::sim::simulate`] but with time-varying rates).
pub fn evaluate(
    spec: &SystemSpec,
    beta: &[f64],
    model: TimingModel,
    link_profiles: &[Profile],
    compute_profiles: &[Profile],
) -> TimeVaryResult {
    let n = spec.n();
    let m = spec.m();
    assert_eq!(beta.len(), n * m);
    assert_eq!(link_profiles.len(), n);
    assert_eq!(compute_profiles.len(), m);
    let g = spec.g();
    let r = spec.releases();
    let a = spec.a();

    // Greedy replay of the sequential protocol (source order × proc
    // order is a DAG; a fixed-point sweep suffices and stays simple).
    let mut ts = vec![0.0; n * m];
    let mut tf = vec![0.0; n * m];
    for i in 0..n {
        for j in 0..m {
            let mut start = if j == 0 { r[i] } else { tf[i * m + j - 1] };
            if i > 0 {
                start = start.max(tf[(i - 1) * m + j]);
            }
            ts[i * m + j] = start;
            tf[i * m + j] = link_profiles[i].finish_time(start, beta[i * m + j] * g[i]);
        }
    }
    let mut compute_done = vec![0.0; m];
    for j in 0..m {
        let total: f64 = (0..n).map(|i| beta[i * m + j]).sum();
        if total <= 0.0 {
            continue;
        }
        match model {
            TimingModel::NoFrontEnd => {
                let last = (0..n).fold(0.0f64, |acc, i| acc.max(tf[i * m + j]));
                compute_done[j] = compute_profiles[j].finish_time(last, total * a[j]);
            }
            TimingModel::FrontEnd => {
                // Stream fraction by fraction.
                let mut end = 0.0f64;
                let mut started = false;
                for i in 0..n {
                    let amount = beta[i * m + j];
                    if amount <= 0.0 {
                        continue;
                    }
                    let begin = if started { end.max(ts[i * m + j]) } else { ts[i * m + j] };
                    started = true;
                    end = compute_profiles[j]
                        .finish_time(begin, amount * a[j])
                        .max(tf[i * m + j]);
                }
                compute_done[j] = end;
            }
        }
    }
    let makespan = compute_done.iter().cloned().fold(0.0, f64::max);
    TimeVaryResult { makespan, compute_done }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dlt::no_frontend::NfeOptions;
    use crate::dlt::Schedule;
    use crate::model::SystemSpec;

    fn nfe_solve(spec: &SystemSpec) -> Schedule {
        crate::pipeline::solve(&NfeOptions::default(), spec).unwrap()
    }

    fn spec() -> SystemSpec {
        SystemSpec::builder()
            .source(0.2, 0.0)
            .source(0.2, 5.0)
            .processors(&[2.0, 3.0, 4.0])
            .job(100.0)
            .build()
            .unwrap()
    }

    #[test]
    fn profile_finish_time_math() {
        let p = Profile::nominal();
        assert_eq!(p.finish_time(3.0, 4.0), 7.0);
        // Half capacity from t=2 to t=6: work 4 starting at 0 ->
        // 2 units done by t=2, remaining 2 at half speed -> 4 more.
        let p = Profile::with_interference(2.0, 6.0, 0.5);
        assert!((p.finish_time(0.0, 4.0) - 6.0).abs() < 1e-12);
        // Work entirely inside the slow window.
        assert!((p.finish_time(2.0, 1.0) - 4.0).abs() < 1e-12);
        // Zero work is free.
        assert_eq!(p.finish_time(1.5, 0.0), 1.5);
    }

    #[test]
    fn profile_validation() {
        assert!(Profile::nominal().check().is_ok());
        assert!(Profile { pieces: vec![(1.0, 1.0)] }.check().is_err());
        assert!(Profile { pieces: vec![(0.0, 1.0), (0.0, 0.5)] }.check().is_err());
        assert!(Profile { pieces: vec![(0.0, 0.0)] }.check().is_err());
    }

    #[test]
    fn nominal_profiles_match_des() {
        let s = spec();
        let sched = nfe_solve(&s);
        let res = evaluate(
            &s,
            &sched.beta,
            TimingModel::NoFrontEnd,
            &vec![Profile::nominal(); 2],
            &vec![Profile::nominal(); 3],
        );
        let des = crate::sim::simulate(&s, &sched.beta, &Default::default());
        assert!(
            (res.makespan - des.makespan).abs() < 1e-9,
            "timevary {} vs des {}",
            res.makespan,
            des.makespan
        );
    }

    #[test]
    fn interference_only_hurts() {
        let s = spec();
        let sched = nfe_solve(&s);
        let nominal = evaluate(
            &s,
            &sched.beta,
            TimingModel::NoFrontEnd,
            &vec![Profile::nominal(); 2],
            &vec![Profile::nominal(); 3],
        );
        // A background job steals 60% of P1 during the compute phase.
        let mut cp = vec![Profile::nominal(); 3];
        cp[0] = Profile::with_interference(30.0, 90.0, 0.6);
        let hit = evaluate(
            &s,
            &sched.beta,
            TimingModel::NoFrontEnd,
            &vec![Profile::nominal(); 2],
            &cp,
        );
        assert!(hit.makespan > nominal.makespan, "{} !> {}", hit.makespan, nominal.makespan);
        // ...and only P1 is affected.
        assert!(hit.compute_done[1] - nominal.compute_done[1] < 1e-9);
    }

    #[test]
    fn link_interference_delays_downstream() {
        let s = spec();
        let sched = nfe_solve(&s);
        let mut lp = vec![Profile::nominal(); 2];
        lp[0] = Profile::with_interference(0.0, 10.0, 0.5);
        let res = evaluate(
            &s,
            &sched.beta,
            TimingModel::NoFrontEnd,
            &lp,
            &vec![Profile::nominal(); 3],
        );
        let nominal = evaluate(
            &s,
            &sched.beta,
            TimingModel::NoFrontEnd,
            &vec![Profile::nominal(); 2],
            &vec![Profile::nominal(); 3],
        );
        assert!(res.makespan > nominal.makespan);
    }
}
