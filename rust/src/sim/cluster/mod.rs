//! Component-based discrete-event cluster engine.
//!
//! The ROADMAP's simulator rewrite: instead of the legacy
//! fixed-function replayer ([`crate::sim::engine`], kept as a parity
//! oracle), the system is modeled as components — [`Source`], [`Link`]
//! and [`Processor`] — implementing the [`Component`] trait over a
//! binary min-heap tick queue:
//!
//! ```text
//!            ┌────────────────────────────────────────────┐
//!            │ ClusterSim                                 │
//!            │  TickQueue (time, lid, seq)  ── pops ──┐   │
//!            │  pending[lid] / wake_at[lid]           ▼   │
//!            │ ┌────────┐   ┌────────┐   ┌───────────────┐│
//!            │ │Source i│──▶│ Link i │──▶│ Processor j   ││
//!            │ │ sends  │   │transfer│   │ ingest+compute││
//!            │ └────────┘   └────────┘   └───────────────┘│
//!            │       ▲  Ctx::wake(lid, t)  │              │
//!            │       └─────────────────────┘              │
//!            │              World (flat shared arrays)    │
//!            └────────────────────────────────────────────┘
//! ```
//!
//! Determinism contract: ticks are ordered by `(time, logical id,
//! seq)`, and logical ids are assigned by role (`sources 0..N`, `links
//! N..2N`, `processors 2N..2N+M`) — never by arena position — so the
//! run is bit-deterministic under a fixed seed and invariant to the
//! order components were inserted into the arena (audited by
//! [`ClusterSim::new_with_arena_order`] in the fuzz tests).
//!
//! Scale discipline (the 10k-processor story): components live in a
//! flat arena, the heap is reserved up front, processors read arrivals
//! straight from the [`World`] arrays, and a steady-state `run()`
//! performs **zero** allocations (asserted by a counting-allocator
//! test) — the same discipline as [`crate::lp::SolverScratch`].
//!
//! The scheduling protocol keeps at most one *live* queue entry per
//! component: `pending[lid]` is the component's currently scheduled
//! tick (superseded entries are skipped as stale on pop), and
//! `wake_at[lid]` persists future wake requests so an earlier tick can
//! never drop them. Component `tick`s are idempotent re-evaluations,
//! which makes duplicate same-time ticks harmless.

pub mod components;
pub mod inject;
pub mod profile;
pub mod queue;

pub use components::{Link, Processor, Source, World};
pub use inject::{FaultSpec, InjectionPlan, LinkWindow};
pub use profile::{finish_with_windows, BlockWindow, Profile};
pub use queue::{TickQueue, Time};

/// One simulated entity scheduled by the engine.
pub trait Component {
    /// The next time this component wants to tick on its own
    /// initiative (used to seed the queue and to re-arm after each
    /// tick); `None` for purely wake-driven components.
    fn next_tick(&self) -> Option<Time>;

    /// React to the clock reaching `now`: inspect and update the
    /// shared [`World`] through `ctx`, and request future ticks with
    /// [`Ctx::wake`]. Must be idempotent — the engine may deliver
    /// duplicate or spurious ticks.
    fn tick(&mut self, now: Time, ctx: &mut Ctx);
}

/// What a component sees while ticking: the shared world plus a wake
/// request buffer the engine drains after the tick.
#[derive(Debug)]
pub struct Ctx {
    /// The shared simulation state.
    pub world: World,
    wakes: Vec<(u32, Time)>,
}

impl Ctx {
    /// Request that component `lid` ticks (again) at time `t`; times
    /// in the past are clamped to the current tick time.
    pub fn wake(&mut self, lid: u32, t: Time) {
        self.wakes.push((lid, t));
    }
}

/// Engine instrumentation counters.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct EngineStats {
    /// Ticks delivered to components.
    pub events: u64,
    /// Superseded queue entries skipped on pop.
    pub stale: u64,
    /// Ticks delivered per component, indexed by logical id.
    pub per_component: Vec<u64>,
    /// Queue-depth high-water mark.
    pub queue_high_water: usize,
    /// Total queue pushes.
    pub pushes: u64,
}

/// The discrete-event engine: a component arena driven by a
/// [`TickQueue`].
pub struct ClusterSim {
    components: Vec<Box<dyn Component>>,
    /// Logical id → arena index.
    arena_of: Vec<usize>,
    /// Currently scheduled tick per component (`INFINITY` = none).
    pending: Vec<Time>,
    /// Earliest outstanding wake request per component.
    wake_at: Vec<Time>,
    queue: TickQueue,
    ctx: Ctx,
    events: u64,
    stale: u64,
    per_component: Vec<u64>,
    now: Time,
}

impl std::fmt::Debug for ClusterSim {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("ClusterSim")
            .field("components", &self.components.len())
            .field("events", &self.events)
            .field("now", &self.now)
            .finish_non_exhaustive()
    }
}

impl ClusterSim {
    /// Build the standard arena for `world`: sources, links and
    /// processors stored in logical-id order.
    pub fn new(world: World) -> ClusterSim {
        let order: Vec<usize> = (0..world.component_count()).collect();
        ClusterSim::new_with_arena_order(world, &order)
    }

    /// Build the arena in an arbitrary insertion order (`order[p]` is
    /// the logical id stored at arena position `p`). Results must be
    /// identical for every permutation — this constructor exists so
    /// tests can prove it.
    pub fn new_with_arena_order(world: World, order: &[usize]) -> ClusterSim {
        let ncomp = world.component_count();
        assert_eq!(order.len(), ncomp, "arena order must cover every component");
        let mut arena_of = vec![usize::MAX; ncomp];
        let mut components: Vec<Box<dyn Component>> = Vec::with_capacity(ncomp);
        for (pos, &lid) in order.iter().enumerate() {
            assert!(
                lid < ncomp && arena_of[lid] == usize::MAX,
                "arena order must be a permutation of 0..{ncomp}"
            );
            arena_of[lid] = pos;
            let c: Box<dyn Component> = if lid < world.n {
                Box::new(Source::new(&world, lid))
            } else if lid < 2 * world.n {
                Box::new(Link::new(lid - world.n))
            } else {
                Box::new(Processor::new(&world, lid - 2 * world.n))
            };
            components.push(c);
        }
        let mut queue = TickQueue::new();
        // Liberal bound on total pushes (≤ ~5 per transfer + wakes), so
        // steady-state runs never grow the heap.
        queue.reserve(10 * world.n * world.m + 4 * (world.n + world.m) + 64);
        let wakes = Vec::with_capacity(16);
        ClusterSim {
            components,
            arena_of,
            pending: vec![Time::INFINITY; ncomp],
            wake_at: vec![Time::INFINITY; ncomp],
            queue,
            ctx: Ctx { world, wakes },
            events: 0,
            stale: 0,
            per_component: vec![0; ncomp],
            now: 0.0,
        }
    }

    /// The shared world (read results here after [`ClusterSim::run`]).
    pub fn world(&self) -> &World {
        &self.ctx.world
    }

    /// Consume the engine, returning the world.
    pub fn into_world(self) -> World {
        self.ctx.world
    }

    /// Instrumentation snapshot.
    pub fn stats(&self) -> EngineStats {
        EngineStats {
            events: self.events,
            stale: self.stale,
            per_component: self.per_component.clone(),
            queue_high_water: self.queue.high_water,
            pushes: self.queue.pushed,
        }
    }

    fn schedule(&mut self, lid: u32, t: Time) {
        let l = lid as usize;
        if t < self.pending[l] {
            self.queue.push(t, lid);
            self.pending[l] = t;
        }
    }

    fn drain_wakes(&mut self) {
        while let Some((lid, t)) = self.ctx.wakes.pop() {
            let l = lid as usize;
            // Never schedule into the past (a processor can "complete"
            // work whose analytic finish predates the final arrival).
            let t = t.max(self.now);
            if t < self.wake_at[l] {
                self.wake_at[l] = t;
            }
            let at = self.wake_at[l];
            self.schedule(lid, at);
        }
    }

    /// Run to quiescence: pop ticks in `(time, lid, seq)` order until
    /// the queue drains.
    pub fn run(&mut self) {
        for lid in 0..self.arena_of.len() {
            let a = self.arena_of[lid];
            if let Some(t) = self.components[a].next_tick() {
                self.schedule(lid as u32, t);
            }
        }
        while let Some((t, lid)) = self.queue.pop() {
            let l = lid as usize;
            if self.pending[l] != t {
                self.stale += 1;
                continue;
            }
            self.pending[l] = Time::INFINITY;
            self.now = t;
            // Consume the wake that fired; future wakes stay armed.
            if self.wake_at[l] <= t {
                self.wake_at[l] = Time::INFINITY;
            }
            let a = self.arena_of[l];
            self.components[a].tick(t, &mut self.ctx);
            self.events += 1;
            self.per_component[l] += 1;
            self.drain_wakes();
            let desired = match self.components[a].next_tick() {
                Some(w) => w.min(self.wake_at[l]),
                None => self.wake_at[l],
            };
            if desired.is_finite() {
                self.schedule(lid, desired.max(t));
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dlt::schedule::TimingModel;
    use crate::model::SystemSpec;

    fn tiny_world(model: TimingModel) -> World {
        let spec = SystemSpec::builder()
            .source(0.2, 0.0)
            .source(0.2, 5.0)
            .processors(&[2.0, 3.0, 4.0])
            .job(100.0)
            .build()
            .unwrap();
        let beta = vec![20.0, 15.0, 10.0, 25.0, 18.0, 12.0];
        World::new(&spec, &beta, model)
    }

    #[test]
    fn run_respects_sequential_rules() {
        let mut sim = ClusterSim::new(tiny_world(TimingModel::NoFrontEnd));
        sim.run();
        let w = sim.world();
        let (n, m) = (w.n, w.m);
        assert!(w.makespan() > 0.0);
        for i in 0..n {
            for j in 0..m - 1 {
                assert!(w.send_done[i * m + j] <= w.send_start[i * m + j + 1] + 1e-12);
            }
        }
        for j in 0..m {
            for i in 0..n - 1 {
                assert!(w.send_done[i * m + j] <= w.send_start[(i + 1) * m + j] + 1e-12);
            }
        }
        let stats = sim.stats();
        assert!(stats.events > 0);
        assert_eq!(stats.per_component.iter().sum::<u64>(), stats.events);
        assert!(stats.queue_high_water >= 1);
    }

    #[test]
    fn arena_order_does_not_change_results() {
        let mut a = ClusterSim::new(tiny_world(TimingModel::FrontEnd));
        a.run();
        let order: Vec<usize> = (0..7).rev().collect();
        let mut b = ClusterSim::new_with_arena_order(tiny_world(TimingModel::FrontEnd), &order);
        b.run();
        assert_eq!(a.world().send_start, b.world().send_start);
        assert_eq!(a.world().send_done, b.world().send_done);
        assert_eq!(a.world().compute_done, b.world().compute_done);
        assert_eq!(a.stats(), b.stats());
    }

    #[test]
    #[should_panic(expected = "permutation")]
    fn arena_order_must_be_a_permutation() {
        ClusterSim::new_with_arena_order(tiny_world(TimingModel::NoFrontEnd), &[0; 7]);
    }

    #[test]
    fn send_gates_delay_sends() {
        let mut w = tiny_world(TimingModel::NoFrontEnd);
        let mut gates = vec![0.0; 6];
        gates[0] = 2.5; // hold S1 -> P1 until t = 2.5
        w.gate_send = Some(gates);
        let mut sim = ClusterSim::new(w);
        sim.run();
        assert_eq!(sim.world().send_start[0], 2.5);
        // Ungated baseline starts at the release time.
        let mut base = ClusterSim::new(tiny_world(TimingModel::NoFrontEnd));
        base.run();
        assert_eq!(base.world().send_start[0], 0.0);
        assert!(sim.world().makespan() >= base.world().makespan());
    }
}
