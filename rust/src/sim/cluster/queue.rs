//! Tick queue: a binary min-heap of `(time, component-id, seq)` keys.
//!
//! Ordering is the engine's determinism contract: earlier time first,
//! then lower *logical* component id, then push sequence. Because the
//! tie-break is the logical id (assigned by role, not by arena
//! position), the pop order — and therefore every simulation result —
//! is invariant to the order components were inserted into the arena.
//!
//! The heap is a hand-rolled sift-up/sift-down over a flat `Vec` so
//! capacity can be reserved up front: once [`TickQueue::reserve`] has
//! sized the buffer, pushes and pops never touch the allocator (the
//! `lp::SolverScratch` discipline, enforced by the 10k-processor
//! allocation test).

/// Simulation clock type.
pub type Time = f64;

/// One heap entry: `(time, logical component id, push sequence)`.
#[derive(Debug, Clone, Copy)]
struct Entry {
    time: Time,
    lid: u32,
    seq: u64,
}

impl Entry {
    /// Strict weak order: time, then logical id, then sequence.
    fn before(&self, other: &Entry) -> bool {
        if self.time != other.time {
            return self.time < other.time;
        }
        if self.lid != other.lid {
            return self.lid < other.lid;
        }
        self.seq < other.seq
    }
}

/// Binary min-heap keyed by `(time, component-id, seq)`.
#[derive(Debug, Default)]
pub struct TickQueue {
    heap: Vec<Entry>,
    next_seq: u64,
    /// Total entries ever pushed (engine metric).
    pub pushed: u64,
    /// Largest heap length observed (queue-depth high-water mark).
    pub high_water: usize,
}

impl TickQueue {
    /// Empty queue.
    pub fn new() -> TickQueue {
        TickQueue::default()
    }

    /// Pre-size the backing buffer so steady-state pushes are
    /// allocation-free.
    pub fn reserve(&mut self, capacity: usize) {
        self.heap.reserve(capacity);
    }

    /// Current backing-buffer capacity (for allocation audits).
    pub fn capacity(&self) -> usize {
        self.heap.capacity()
    }

    /// Schedule component `lid` to tick at `time`.
    pub fn push(&mut self, time: Time, lid: u32) {
        debug_assert!(time.is_finite(), "non-finite tick time");
        let e = Entry { time, lid, seq: self.next_seq };
        self.next_seq += 1;
        self.pushed += 1;
        self.heap.push(e);
        // Sift up.
        let mut i = self.heap.len() - 1;
        while i > 0 {
            let parent = (i - 1) / 2;
            if self.heap[i].before(&self.heap[parent]) {
                self.heap.swap(i, parent);
                i = parent;
            } else {
                break;
            }
        }
        if self.heap.len() > self.high_water {
            self.high_water = self.heap.len();
        }
    }

    /// Pop the earliest `(time, lid)` entry.
    pub fn pop(&mut self) -> Option<(Time, u32)> {
        if self.heap.is_empty() {
            return None;
        }
        let last = self.heap.len() - 1;
        self.heap.swap(0, last);
        let out = self.heap.pop().unwrap();
        // Sift down.
        let len = self.heap.len();
        let mut i = 0;
        loop {
            let l = 2 * i + 1;
            let r = 2 * i + 2;
            let mut best = i;
            if l < len && self.heap[l].before(&self.heap[best]) {
                best = l;
            }
            if r < len && self.heap[r].before(&self.heap[best]) {
                best = r;
            }
            if best == i {
                break;
            }
            self.heap.swap(i, best);
            i = best;
        }
        Some((out.time, out.lid))
    }

    /// Number of pending entries.
    pub fn len(&self) -> usize {
        self.heap.len()
    }

    /// True when no entries remain.
    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn orders_by_time_then_lid_then_seq() {
        let mut q = TickQueue::new();
        q.push(2.0, 9);
        q.push(1.0, 5);
        q.push(1.0, 3); // same time, lower lid: wins despite later push
        q.push(1.0, 5); // duplicate (time, lid): earlier seq first
        assert_eq!(q.pop(), Some((1.0, 3)));
        assert_eq!(q.pop(), Some((1.0, 5)));
        assert_eq!(q.pop(), Some((1.0, 5)));
        assert_eq!(q.pop(), Some((2.0, 9)));
        assert_eq!(q.pop(), None);
    }

    #[test]
    fn heap_property_under_random_load() {
        use crate::util::rng::{Pcg32, Rng};
        let mut rng = Pcg32::new(11);
        let mut q = TickQueue::new();
        for _ in 0..500 {
            q.push((rng.f64() * 100.0).floor(), rng.below(10) as u32);
        }
        let mut prev = (f64::NEG_INFINITY, 0u32);
        let mut n = 0;
        while let Some((t, lid)) = q.pop() {
            assert!(t > prev.0 || (t == prev.0 && lid >= prev.1), "order broke at {t}/{lid}");
            prev = (t, lid);
            n += 1;
        }
        assert_eq!(n, 500);
        assert_eq!(q.pushed, 500);
        assert!(q.high_water <= 500);
    }

    #[test]
    fn reserve_prevents_growth() {
        let mut q = TickQueue::new();
        q.reserve(64);
        let cap = q.capacity();
        for k in 0..64 {
            q.push(k as f64, 0);
        }
        assert_eq!(q.capacity(), cap);
    }
}
