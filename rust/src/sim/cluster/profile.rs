//! Time-varying rate policy for cluster components.
//!
//! [`Profile`] is the piecewise-constant *capacity multiplier* `c(t) >
//! 0` (1.0 = nominal) that used to live in the standalone
//! `sim::timevary` module (paper §8, "processing speed become
//! time-varying"): work that nominally takes `w` time units completes
//! when the integral of `c` reaches `w`. Here it is a component
//! policy — every [`super::components::Link`] owns one, and processors
//! evaluate their compute chunks through one — instead of a separate
//! fixed-function replayer.
//!
//! [`finish_with_windows`] layers the injection windows on top: spans
//! where the component is *blocked outright* (a failed processor, a
//! preempted CPU). Progress pauses across a window; a `redo` window
//! additionally discards all progress on the in-flight chunk (the
//! fail/restart semantics — the processor re-requests the work).

use super::queue::Time;

/// Piecewise-constant capacity multiplier.
#[derive(Debug, Clone, PartialEq)]
pub struct Profile {
    /// Breakpoints: `(start_time, multiplier)`; first entry must start
    /// at 0. Multipliers must be > 0.
    pub pieces: Vec<(f64, f64)>,
}

impl Profile {
    /// Constant nominal capacity.
    pub fn nominal() -> Profile {
        Profile { pieces: vec![(0.0, 1.0)] }
    }

    /// A background job occupies `share` of the node during
    /// `[from, to)` (capacity drops to `1 − share`).
    pub fn with_interference(from: f64, to: f64, share: f64) -> Profile {
        assert!((0.0..1.0).contains(&share), "share in [0,1)");
        assert!(from >= 0.0 && to > from);
        let mut pieces = vec![(0.0, 1.0)];
        if from > 0.0 {
            pieces.push((from, 1.0 - share));
        } else {
            pieces[0].1 = 1.0 - share;
        }
        pieces.push((to, 1.0));
        Profile { pieces }
    }

    /// Build from multiplicative slowdown windows `(from, to, factor)`.
    /// Overlapping windows compound (factors multiply); outside every
    /// window the capacity is nominal. Factors must be in `(0, ∞)`.
    pub fn from_windows(windows: &[(f64, f64, f64)]) -> Profile {
        if windows.is_empty() {
            return Profile::nominal();
        }
        let mut cuts: Vec<f64> = vec![0.0];
        for &(from, to, _) in windows {
            assert!(from >= 0.0 && to > from, "window must satisfy 0 <= from < to");
            cuts.push(from);
            cuts.push(to);
        }
        cuts.sort_by(|x, y| x.partial_cmp(y).unwrap());
        cuts.dedup();
        let mut pieces: Vec<(f64, f64)> = Vec::with_capacity(cuts.len());
        for &t in &cuts {
            let cap: f64 = windows
                .iter()
                .filter(|&&(from, to, _)| from <= t && t < to)
                .map(|&(_, _, f)| f)
                .product();
            match pieces.last() {
                Some(&(_, last_cap)) if last_cap == cap => {}
                _ => pieces.push((t, cap)),
            }
        }
        Profile { pieces }
    }

    /// Validate invariants.
    pub fn check(&self) -> Result<(), String> {
        if self.pieces.is_empty() || self.pieces[0].0 != 0.0 {
            return Err("profile must start at t = 0".into());
        }
        for w in self.pieces.windows(2) {
            if w[1].0 <= w[0].0 {
                return Err("breakpoints must increase".into());
            }
        }
        if self.pieces.iter().any(|&(_, c)| c <= 0.0) {
            return Err("multipliers must be > 0".into());
        }
        Ok(())
    }

    /// Time at which `work` nominal units complete when started at
    /// `start` under this profile.
    pub fn finish_time(&self, start: Time, work: f64) -> Time {
        debug_assert!(self.check().is_ok());
        if work <= 0.0 {
            return start;
        }
        if start.is_infinite() {
            return Time::INFINITY;
        }
        let mut remaining = work;
        let mut t = start;
        let mut idx = self.pieces.iter().rposition(|&(s, _)| s <= t).unwrap_or(0);
        loop {
            let (_, cap) = self.pieces[idx];
            let piece_end = self.pieces.get(idx + 1).map(|&(s, _)| s).unwrap_or(f64::INFINITY);
            let span = piece_end - t;
            let doable = span * cap;
            if doable >= remaining {
                return t + remaining / cap;
            }
            remaining -= doable;
            t = piece_end;
            idx += 1;
        }
    }

    /// Nominal work units completed between `t0` and `t1` (the
    /// integral of the capacity multiplier over `[t0, t1)`).
    pub fn work_between(&self, t0: Time, t1: Time) -> f64 {
        debug_assert!(self.check().is_ok());
        if t1 <= t0 {
            return 0.0;
        }
        let mut total = 0.0;
        let mut t = t0;
        let mut idx = self.pieces.iter().rposition(|&(s, _)| s <= t).unwrap_or(0);
        while t < t1 {
            let (_, cap) = self.pieces[idx];
            let piece_end = self.pieces.get(idx + 1).map(|&(s, _)| s).unwrap_or(f64::INFINITY);
            let upto = piece_end.min(t1);
            total += (upto - t) * cap;
            t = upto;
            idx += 1;
        }
        total
    }
}

/// A blocking window `(from, to, redo)`: no progress inside
/// `[from, to)`; when `redo` is set, crossing the window also resets
/// the in-flight chunk to its full size (fail/restart: partial work is
/// lost and redone).
pub type BlockWindow = (Time, Time, bool);

/// Completion time of `work` nominal units started at `start` under
/// `profile`, with progress suspended across each of the sorted,
/// non-overlapping `windows`.
///
/// Returns `Time::INFINITY` if a window never closes (`to` = ∞) and
/// the work cannot complete before it opens.
pub fn finish_with_windows(
    profile: &Profile,
    windows: &[BlockWindow],
    start: Time,
    work: f64,
) -> Time {
    if work <= 0.0 {
        return start;
    }
    let mut t = start;
    let mut remaining = work;
    let mut idx = 0;
    loop {
        if t.is_infinite() {
            return Time::INFINITY;
        }
        // Skip windows that ended before the cursor.
        while idx < windows.len() && windows[idx].1 <= t {
            idx += 1;
        }
        // Inside a window: jump to its end; a redo window discards the
        // chunk's progress.
        if idx < windows.len() && windows[idx].0 <= t {
            let (_, to, redo) = windows[idx];
            if redo {
                remaining = work;
            }
            t = to;
            idx += 1;
            continue;
        }
        let open_until = if idx < windows.len() { windows[idx].0 } else { f64::INFINITY };
        let fin = profile.finish_time(t, remaining);
        if fin <= open_until {
            return fin;
        }
        remaining -= profile.work_between(t, open_until);
        t = open_until;
        // Cursor now sits exactly on windows[idx].from; next iteration
        // takes the inside-a-window branch and consumes it.
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // Migrated from the deleted `sim::timevary` module.
    #[test]
    fn profile_finish_time_math() {
        let p = Profile::nominal();
        assert_eq!(p.finish_time(3.0, 4.0), 7.0);
        // Half capacity from t=2 to t=6: work 4 starting at 0 ->
        // 2 units done by t=2, remaining 2 at half speed -> 4 more.
        let p = Profile::with_interference(2.0, 6.0, 0.5);
        assert!((p.finish_time(0.0, 4.0) - 6.0).abs() < 1e-12);
        // Work entirely inside the slow window.
        assert!((p.finish_time(2.0, 1.0) - 4.0).abs() < 1e-12);
        // Zero work is free.
        assert_eq!(p.finish_time(1.5, 0.0), 1.5);
    }

    // Migrated from the deleted `sim::timevary` module.
    #[test]
    fn profile_validation() {
        assert!(Profile::nominal().check().is_ok());
        assert!(Profile { pieces: vec![(1.0, 1.0)] }.check().is_err());
        assert!(Profile { pieces: vec![(0.0, 1.0), (0.0, 0.5)] }.check().is_err());
        assert!(Profile { pieces: vec![(0.0, 0.0)] }.check().is_err());
    }

    #[test]
    fn work_between_integrates_capacity() {
        let p = Profile::with_interference(2.0, 6.0, 0.5);
        assert!((p.work_between(0.0, 2.0) - 2.0).abs() < 1e-12);
        assert!((p.work_between(0.0, 6.0) - 4.0).abs() < 1e-12);
        assert!((p.work_between(3.0, 5.0) - 1.0).abs() < 1e-12);
        assert_eq!(p.work_between(5.0, 5.0), 0.0);
    }

    #[test]
    fn from_windows_compounds_overlaps() {
        let p = Profile::from_windows(&[(1.0, 3.0, 0.5), (2.0, 4.0, 0.5)]);
        assert!(p.check().is_ok());
        assert!((p.work_between(0.0, 1.0) - 1.0).abs() < 1e-12);
        assert!((p.work_between(1.0, 2.0) - 0.5).abs() < 1e-12);
        // Both windows active in [2, 3): capacity 0.25.
        assert!((p.work_between(2.0, 3.0) - 0.25).abs() < 1e-12);
        assert!((p.work_between(3.0, 4.0) - 0.5).abs() < 1e-12);
        assert!((p.work_between(4.0, 5.0) - 1.0).abs() < 1e-12);
        assert_eq!(Profile::from_windows(&[]), Profile::nominal());
    }

    #[test]
    fn windows_pause_and_redo() {
        let nominal = Profile::nominal();
        // Pause: 3 units of work starting at 0, blocked during [1, 5):
        // 1 unit done, 4 idle, 2 more -> finishes at 7.
        let t = finish_with_windows(&nominal, &[(1.0, 5.0, false)], 0.0, 3.0);
        assert!((t - 7.0).abs() < 1e-12);
        // Redo: same shape but progress is lost -> full 3 units after
        // the window -> finishes at 8.
        let t = finish_with_windows(&nominal, &[(1.0, 5.0, true)], 0.0, 3.0);
        assert!((t - 8.0).abs() < 1e-12);
        // Work that fits before the window is unaffected.
        let t = finish_with_windows(&nominal, &[(4.0, 5.0, true)], 0.0, 3.0);
        assert!((t - 3.0).abs() < 1e-12);
        // Starting inside a window waits it out first.
        let t = finish_with_windows(&nominal, &[(1.0, 5.0, false)], 2.0, 1.0);
        assert!((t - 6.0).abs() < 1e-12);
        // A window that never closes pins completion at infinity.
        let t = finish_with_windows(&nominal, &[(1.0, f64::INFINITY, false)], 0.0, 3.0);
        assert!(t.is_infinite());
    }

    #[test]
    fn windows_compose_with_profiles() {
        // Half speed from t=0 to t=10, blocked during [2, 4): work 3
        // does 1 unit by t=2, waits to 4, needs 4 more half-speed time
        // units for the remaining 2 -> finishes at 8.
        let p = Profile::with_interference(0.0, 10.0, 0.5);
        let t = finish_with_windows(&p, &[(2.0, 4.0, false)], 0.0, 3.0);
        assert!((t - 8.0).abs() < 1e-12, "got {t}");
    }
}
