//! Injection layer: faults, preemption, time-varying link speed.
//!
//! An [`InjectionPlan`] is the declarative description of everything
//! adverse that happens during a replay. It is built either from CLI
//! grammar strings (`dlt simulate --fail p3@t=1.5 --preempt
//! "p2@4+1.5!redo" --link-profile s1@10+5*0.25`) or programmatically,
//! and is *resolved* against a concrete system just before the run:
//! random faults are materialized from the seed, default durations are
//! filled in from the predicted makespan, and overlapping windows are
//! merged into the sorted per-processor [`BlockWindow`] lists the
//! components consume.
//!
//! Semantics:
//!
//! - **Fail/restart** (`--fail`): the processor is down for the window
//!   — it neither receives nor computes — and the in-flight compute
//!   chunk is lost and redone from scratch after restart.
//! - **Preemption** (`--preempt`): the processor loses its CPU but
//!   keeps its front-end — transfers continue, compute pauses. With
//!   the `!redo` suffix the preempted chunk is re-requested instead of
//!   resumed.
//! - **Link window** (`--link-profile`): a source's outgoing link runs
//!   at a capacity multiple for a span (`s1@10+5*0.25` = source 1,
//!   quarter speed for 5 time units starting at t = 10).

use crate::error::{Error, Result};
use crate::util::rng::{Pcg32, Rng};

use super::profile::{BlockWindow, Profile};
use super::queue::Time;

/// One injected outage on a processor.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FaultSpec {
    /// Target processor (0-based).
    pub processor: usize,
    /// Outage start time.
    pub at: Time,
    /// Outage length; `None` defaults to ¼ of the predicted makespan
    /// at resolution time.
    pub duration: Option<f64>,
    /// Lose and redo the in-flight compute chunk (fail/restart, or
    /// preemption with `!redo`).
    pub redo: bool,
    /// The outage also blocks data reception (fail/restart; preemption
    /// leaves the front-end running).
    pub blocks_recv: bool,
}

/// A capacity window on one source's outgoing link.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LinkWindow {
    /// Source whose link is affected (0-based).
    pub source: usize,
    /// Window start time.
    pub from: Time,
    /// Window length.
    pub duration: f64,
    /// Capacity multiplier inside the window (`0 < factor`).
    pub factor: f64,
}

/// Everything adverse injected into one replay.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct InjectionPlan {
    /// Scheduled outages (fail/restart and preemption).
    pub faults: Vec<FaultSpec>,
    /// Link capacity windows.
    pub link_windows: Vec<LinkWindow>,
    /// Number of additional seeded-random fail/restart outages to draw
    /// at resolution time.
    pub random_faults: usize,
}

/// An [`InjectionPlan`] resolved against a concrete system: sorted,
/// merged, per-component window lists ready for the engine.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Resolved {
    /// Per-processor compute-blocking windows (all outages), sorted
    /// and non-overlapping.
    pub compute_windows: Vec<Vec<BlockWindow>>,
    /// Per-processor receive-blocking windows (fail/restart only).
    pub recv_windows: Vec<Vec<BlockWindow>>,
    /// Per-source link capacity profile.
    pub link_profiles: Vec<Profile>,
    /// Fail/restart outages materialized (scheduled + random).
    pub faults_injected: usize,
    /// Preemption windows materialized.
    pub preemptions: usize,
}

fn bad(what: &str, s: &str, want: &str) -> Error {
    Error::Usage(format!("bad {what} spec '{s}': expected {want}"))
}

fn parse_f64(tok: &str, what: &str, s: &str, want: &str) -> Result<f64> {
    let v: f64 = tok.trim().parse().map_err(|_| bad(what, s, want))?;
    if !v.is_finite() || v < 0.0 {
        return Err(bad(what, s, want));
    }
    Ok(v)
}

/// Parse a 1-based component index like `p3` / `s1` into 0-based.
fn parse_index(tok: &str, prefix: char, what: &str, s: &str, want: &str) -> Result<usize> {
    let rest = tok
        .trim()
        .strip_prefix(prefix)
        .ok_or_else(|| bad(what, s, want))?;
    let idx: usize = rest.parse().map_err(|_| bad(what, s, want))?;
    if idx == 0 {
        return Err(bad(what, s, want));
    }
    Ok(idx - 1)
}

/// Parse the shared `p<J>@[t=]<AT>[+<DUR>]` core of a fault/preempt
/// spec; returns `(processor, at, duration, rest)` where `rest` is any
/// trailing text after the duration (e.g. `!redo`).
fn parse_outage_core<'s>(
    s: &'s str,
    what: &str,
    want: &str,
) -> Result<(usize, Time, Option<f64>, &'s str)> {
    let (proc_tok, when) = s.split_once('@').ok_or_else(|| bad(what, s, want))?;
    let processor = parse_index(proc_tok, 'p', what, s, want)?;
    let when = when.trim().strip_prefix("t=").unwrap_or(when.trim());
    let (at_tok, dur_rest) = match when.split_once('+') {
        Some((a, d)) => (a, Some(d)),
        None => (when, None),
    };
    let at = parse_f64(at_tok, what, s, want)?;
    let (duration, rest) = match dur_rest {
        None => (None, ""),
        Some(d) => {
            let (dur_tok, rest) = match d.find('!') {
                Some(k) => (&d[..k], &d[k..]),
                None => (d, ""),
            };
            let dur = parse_f64(dur_tok, what, s, want)?;
            if dur <= 0.0 {
                return Err(bad(what, s, want));
            }
            (Some(dur), rest)
        }
    };
    Ok((processor, at, duration, rest))
}

impl FaultSpec {
    /// Parse a fail/restart spec: `p3@1.5`, `p3@t=1.5`, `p3@t=1.5+2.0`.
    /// A missing duration defaults to ¼ of the predicted makespan when
    /// the plan is resolved.
    pub fn parse_fail(s: &str) -> Result<FaultSpec> {
        const WANT: &str = "p<J>@[t=]<AT>[+<DURATION>]";
        let (processor, at, duration, rest) = parse_outage_core(s, "--fail", WANT)?;
        if !rest.is_empty() {
            return Err(bad("--fail", s, WANT));
        }
        Ok(FaultSpec { processor, at, duration, redo: true, blocks_recv: true })
    }

    /// Parse a preemption spec: `p2@4+1.5` (resume) or `p2@4+1.5!redo`
    /// (the chunk is re-requested). The duration is mandatory.
    pub fn parse_preempt(s: &str) -> Result<FaultSpec> {
        const WANT: &str = "p<J>@[t=]<AT>+<DURATION>[!redo]";
        let (processor, at, duration, rest) = parse_outage_core(s, "--preempt", WANT)?;
        let duration = match duration {
            Some(d) => Some(d),
            None => return Err(bad("--preempt", s, WANT)),
        };
        let redo = match rest {
            "" => false,
            "!redo" => true,
            _ => return Err(bad("--preempt", s, WANT)),
        };
        Ok(FaultSpec { processor, at, duration, redo, blocks_recv: false })
    }
}

impl LinkWindow {
    /// Parse a link capacity window: `s1@10+5*0.25` (source 1 runs at
    /// ×0.25 capacity for 5 time units starting at t = 10).
    pub fn parse(s: &str) -> Result<LinkWindow> {
        const WANT: &str = "s<I>@<FROM>+<DURATION>*<FACTOR>";
        let what = "--link-profile";
        let (src_tok, rest) = s.split_once('@').ok_or_else(|| bad(what, s, WANT))?;
        let source = parse_index(src_tok, 's', what, s, WANT)?;
        let (from_tok, rest) = rest.split_once('+').ok_or_else(|| bad(what, s, WANT))?;
        let (dur_tok, factor_tok) = rest.split_once('*').ok_or_else(|| bad(what, s, WANT))?;
        let from = parse_f64(from_tok, what, s, WANT)?;
        let duration = parse_f64(dur_tok, what, s, WANT)?;
        let factor = parse_f64(factor_tok, what, s, WANT)?;
        if duration <= 0.0 || factor <= 0.0 {
            return Err(bad(what, s, WANT));
        }
        Ok(LinkWindow { source, from, duration, factor })
    }
}

/// Parse a comma-separated list with one of the element parsers above.
pub fn parse_list<T>(s: &str, parse_one: impl Fn(&str) -> Result<T>) -> Result<Vec<T>> {
    s.split(',')
        .map(str::trim)
        .filter(|t| !t.is_empty())
        .map(parse_one)
        .collect()
}

/// Merge possibly-overlapping `(from, to, redo)` windows into a
/// sorted, non-overlapping list; overlapping windows OR their redo
/// flags.
fn merge_windows(mut ws: Vec<BlockWindow>) -> Vec<BlockWindow> {
    ws.sort_by(|x, y| x.0.partial_cmp(&y.0).unwrap());
    let mut out: Vec<BlockWindow> = Vec::with_capacity(ws.len());
    for w in ws {
        match out.last_mut() {
            Some(last) if w.0 <= last.1 => {
                last.1 = last.1.max(w.1);
                last.2 |= w.2;
            }
            _ => out.push(w),
        }
    }
    out
}

impl InjectionPlan {
    /// True when the plan injects nothing at all.
    pub fn is_empty(&self) -> bool {
        self.faults.is_empty() && self.link_windows.is_empty() && self.random_faults == 0
    }

    /// Resolve against a concrete system: materialize `random_faults`
    /// fail/restart outages from `seed` over `[0, horizon)`, default
    /// missing fail durations to `horizon / 4`, validate indices, and
    /// merge everything into per-component window lists.
    pub fn resolve(&self, n: usize, m: usize, horizon: f64, seed: u64) -> Result<Resolved> {
        let horizon = if horizon.is_finite() && horizon > 0.0 { horizon } else { 1.0 };
        let mut faults: Vec<FaultSpec> = self.faults.clone();
        if self.random_faults > 0 {
            // Domain-separate the fault stream from everything else
            // keyed on the same seed.
            let mut rng = Pcg32::new(seed ^ 0x6661_756C_7472_6E64); // "faulrnd"
            for _ in 0..self.random_faults {
                let processor = rng.below(m);
                let at = rng.f64() * horizon;
                let duration = (0.05 + 0.20 * rng.f64()) * horizon;
                faults.push(FaultSpec {
                    processor,
                    at,
                    duration: Some(duration),
                    redo: true,
                    blocks_recv: true,
                });
            }
        }

        let mut compute: Vec<Vec<BlockWindow>> = vec![Vec::new(); m];
        let mut recv: Vec<Vec<BlockWindow>> = vec![Vec::new(); m];
        let mut faults_injected = 0usize;
        let mut preemptions = 0usize;
        for f in &faults {
            if f.processor >= m {
                return Err(Error::Usage(format!(
                    "outage targets p{} but the system has {m} processors",
                    f.processor + 1
                )));
            }
            let dur = f.duration.unwrap_or(horizon / 4.0);
            let (from, to) = (f.at, f.at + dur);
            compute[f.processor].push((from, to, f.redo));
            if f.blocks_recv {
                recv[f.processor].push((from, to, false));
                faults_injected += 1;
            } else {
                preemptions += 1;
            }
        }
        let compute_windows: Vec<_> = compute.into_iter().map(merge_windows).collect();
        let recv_windows: Vec<_> = recv.into_iter().map(merge_windows).collect();

        let mut per_source: Vec<Vec<(f64, f64, f64)>> = vec![Vec::new(); n];
        for w in &self.link_windows {
            if w.source >= n {
                return Err(Error::Usage(format!(
                    "link window targets s{} but the system has {n} sources",
                    w.source + 1
                )));
            }
            per_source[w.source].push((w.from, w.from + w.duration, w.factor));
        }
        let link_profiles: Vec<Profile> =
            per_source.iter().map(|ws| Profile::from_windows(ws)).collect();

        Ok(Resolved { compute_windows, recv_windows, link_profiles, faults_injected, preemptions })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fail_grammar() {
        let f = FaultSpec::parse_fail("p3@1.5").unwrap();
        assert_eq!(f.processor, 2);
        assert_eq!(f.at, 1.5);
        assert_eq!(f.duration, None);
        assert!(f.redo && f.blocks_recv);
        let f = FaultSpec::parse_fail("p3@t=1.5+2.0").unwrap();
        assert_eq!(f.duration, Some(2.0));
        assert!(FaultSpec::parse_fail("p0@1.0").is_err());
        assert!(FaultSpec::parse_fail("q3@1.0").is_err());
        assert!(FaultSpec::parse_fail("p3@").is_err());
        assert!(FaultSpec::parse_fail("p3@1.0+0.0").is_err());
        assert!(FaultSpec::parse_fail("p3@1.0+2.0!redo").is_err());
        assert!(FaultSpec::parse_fail("p3@-1.0").is_err());
    }

    #[test]
    fn preempt_grammar() {
        let f = FaultSpec::parse_preempt("p2@4+1.5").unwrap();
        assert_eq!((f.processor, f.at, f.duration), (1, 4.0, Some(1.5)));
        assert!(!f.redo && !f.blocks_recv);
        let f = FaultSpec::parse_preempt("p2@t=4+1.5!redo").unwrap();
        assert!(f.redo && !f.blocks_recv);
        assert!(FaultSpec::parse_preempt("p2@4").is_err(), "duration is mandatory");
        assert!(FaultSpec::parse_preempt("p2@4+1.5!later").is_err());
    }

    #[test]
    fn link_grammar() {
        let w = LinkWindow::parse("s1@10+5*0.25").unwrap();
        assert_eq!(w, LinkWindow { source: 0, from: 10.0, duration: 5.0, factor: 0.25 });
        assert!(LinkWindow::parse("s1@10+5").is_err());
        assert!(LinkWindow::parse("s1@10+0*0.5").is_err());
        assert!(LinkWindow::parse("s1@10+5*0").is_err());
        assert!(LinkWindow::parse("p1@10+5*0.5").is_err());
    }

    #[test]
    fn list_parsing() {
        let fs = parse_list("p1@1+1, p2@2+2", FaultSpec::parse_fail).unwrap();
        assert_eq!(fs.len(), 2);
        assert!(parse_list("p1@1+1,junk", FaultSpec::parse_fail).is_err());
        assert!(parse_list("", FaultSpec::parse_fail).unwrap().is_empty());
    }

    #[test]
    fn resolve_merges_and_counts() {
        let plan = InjectionPlan {
            faults: vec![
                FaultSpec::parse_fail("p1@1+2").unwrap(),
                FaultSpec::parse_preempt("p1@2+3").unwrap(), // overlaps the fail
                FaultSpec::parse_preempt("p2@1+1").unwrap(),
            ],
            link_windows: vec![LinkWindow::parse("s1@0+2*0.5").unwrap()],
            random_faults: 0,
        };
        let r = plan.resolve(2, 3, 10.0, 0).unwrap();
        assert_eq!(r.faults_injected, 1);
        assert_eq!(r.preemptions, 2);
        // p1's fail [1,3) and preempt [2,5) merge to one redo window.
        assert_eq!(r.compute_windows[0], vec![(1.0, 5.0, true)]);
        // Only the fail blocks reception.
        assert_eq!(r.recv_windows[0], vec![(1.0, 3.0, false)]);
        assert_eq!(r.compute_windows[1], vec![(1.0, 2.0, false)]);
        assert!(r.recv_windows[1].is_empty());
        assert_eq!(r.compute_windows[2], vec![]);
        assert!((r.link_profiles[0].work_between(0.0, 2.0) - 1.0).abs() < 1e-12);
        assert_eq!(r.link_profiles[1], Profile::nominal());
    }

    #[test]
    fn resolve_fills_default_duration_and_randoms() {
        let plan = InjectionPlan {
            faults: vec![FaultSpec::parse_fail("p1@2").unwrap()],
            link_windows: vec![],
            random_faults: 3,
        };
        let r1 = plan.resolve(1, 4, 8.0, 42).unwrap();
        assert_eq!(r1.faults_injected, 4);
        // Scheduled fault got the default horizon/4 duration.
        assert!(r1.compute_windows.iter().flatten().any(|w| *w == (2.0, 4.0, true)));
        // Same seed, same draw.
        let r2 = plan.resolve(1, 4, 8.0, 42).unwrap();
        assert_eq!(r1, r2);
        let r3 = plan.resolve(1, 4, 8.0, 43).unwrap();
        assert_ne!(r1, r3);
        // Randoms land inside the horizon with positive finite length.
        for ws in &r3.compute_windows {
            for &(from, to, _) in ws {
                assert!(from >= 0.0 && to > from && to.is_finite());
            }
        }
    }

    #[test]
    fn resolve_rejects_out_of_range_targets() {
        let plan = InjectionPlan {
            faults: vec![FaultSpec::parse_fail("p5@1+1").unwrap()],
            ..Default::default()
        };
        assert!(plan.resolve(1, 3, 10.0, 0).is_err());
        let plan = InjectionPlan {
            link_windows: vec![LinkWindow::parse("s3@0+1*0.5").unwrap()],
            ..Default::default()
        };
        assert!(plan.resolve(2, 3, 10.0, 0).is_err());
    }
}
