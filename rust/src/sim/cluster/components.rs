//! The shared [`World`] and the three component kinds.
//!
//! All coordination state lives in the `World` (flat, pre-sized
//! arrays); the components themselves carry only their tiny private
//! state machines. A component's `tick` is an idempotent re-evaluation
//! of "what can I do now?" — duplicate or same-time ticks are harmless
//! no-ops — which is what makes the engine's stale-entry scheduling
//! protocol safe.
//!
//! Timing semantics are the paper's sequential-distribution rules,
//! kept operation-for-operation identical to the legacy
//! [`crate::sim::engine`] so that a jitter-free, fault-free run in
//! [`super::super::replay::Gate::Asap`] mode is bit-compatible with
//! the legacy simulator:
//!
//! - [`Source`] `i` sends to `P_1..P_M` in order; a send starts at
//!   `max(source free, processor receive-free)` — lower-bounded by the
//!   LP's `TS_{i,j}` when send gates are installed.
//! - [`Link`] `i` carries one transfer at a time; its duration is
//!   `β G_i · jitter` integrated through the link's capacity
//!   [`Profile`] and paused across the destination's receive-blocking
//!   windows.
//! - [`Processor`] `j` consumes arrivals in source order straight from
//!   the world arrays (no per-arrival queue): with front-ends it
//!   streams fractions through a compute pipeline; without, it starts
//!   after the last byte arrives. Compute chunks are evaluated through
//!   the processor's outage windows (`redo` windows discard the
//!   in-flight chunk).

use crate::dlt::schedule::TimingModel;
use crate::model::SystemSpec;
use crate::sim::trace::{Trace, TraceKind};

use super::profile::{finish_with_windows, BlockWindow, Profile};
use super::queue::Time;
use super::{Component, Ctx};

/// Shared simulation state: static parameters, injection policies and
/// the flat dynamic arrays every component reads and writes.
#[derive(Debug)]
pub struct World {
    /// Number of sources `N`.
    pub n: usize,
    /// Number of processors `M`.
    pub m: usize,
    /// Inverse link speeds `G_i`.
    pub g: Vec<f64>,
    /// Inverse compute speeds `A_j`.
    pub a: Vec<f64>,
    /// Source release times `R_i`.
    pub release: Vec<f64>,
    /// Load fractions `β` (row-major `N × M`).
    pub beta: Vec<f64>,
    /// Timing model to execute under.
    pub model: TimingModel,
    /// Per-cell multiplicative link jitter factors (`N × M`).
    pub link_factor: Vec<f64>,
    /// Per-processor multiplicative compute jitter factors.
    pub comp_factor: Vec<f64>,
    /// Per-source link capacity profile (time-varying link speed).
    pub link_profile: Vec<Profile>,
    /// Per-processor compute-blocking outage windows (sorted, merged).
    pub compute_windows: Vec<Vec<BlockWindow>>,
    /// Per-processor receive-blocking outage windows (fail/restart).
    pub recv_windows: Vec<Vec<BlockWindow>>,
    /// Optional per-cell lower bounds on send start times (the LP's
    /// `TS_{i,j}`); `None` runs pure ASAP.
    pub gate_send: Option<Vec<f64>>,
    /// Earliest time each source may start its next send.
    pub src_free_at: Vec<Time>,
    /// Next processor index each source sends to.
    pub next_j: Vec<usize>,
    /// Next source index each processor expects to receive from.
    pub proc_expect: Vec<usize>,
    /// Earliest time each processor may start its next receive.
    pub proc_recv_free_at: Vec<Time>,
    /// In-flight transfer destination per source link (`None` = idle).
    pub link_dest: Vec<Option<usize>>,
    /// Completion time of the in-flight transfer per source link.
    pub link_done_at: Vec<Time>,
    /// Realized send start times (`N × M`).
    pub send_start: Vec<Time>,
    /// Realized send completion times (`N × M`).
    pub send_done: Vec<Time>,
    /// Realized per-processor compute completion times.
    pub compute_done: Vec<Time>,
    /// Optional trace tap ([`crate::sim::trace`]); tracing allocates,
    /// leave `None` for allocation-audited runs.
    pub trace: Option<Trace>,
    /// Shared constant-capacity profile for compute evaluation.
    nominal: Profile,
}

impl World {
    /// Fresh world for `spec` executing `beta` under `model`, with
    /// nominal factors and no injections; mutate the policy fields
    /// before building the engine.
    pub fn new(spec: &SystemSpec, beta: &[f64], model: TimingModel) -> World {
        let n = spec.n();
        let m = spec.m();
        assert_eq!(beta.len(), n * m, "beta shape mismatch");
        World {
            n,
            m,
            g: spec.g(),
            a: spec.a(),
            release: spec.releases(),
            beta: beta.to_vec(),
            model,
            link_factor: vec![1.0; n * m],
            comp_factor: vec![1.0; m],
            link_profile: vec![Profile::nominal(); n],
            compute_windows: vec![Vec::new(); m],
            recv_windows: vec![Vec::new(); m],
            gate_send: None,
            src_free_at: spec.releases(),
            next_j: vec![0; n],
            proc_expect: vec![0; m],
            proc_recv_free_at: vec![0.0; m],
            link_dest: vec![None; n],
            link_done_at: vec![0.0; n],
            send_start: vec![0.0; n * m],
            send_done: vec![0.0; n * m],
            compute_done: vec![0.0; m],
            trace: None,
            nominal: Profile::nominal(),
        }
    }

    /// Total component count (`N` sources + `N` links + `M`
    /// processors).
    pub fn component_count(&self) -> usize {
        2 * self.n + self.m
    }

    /// Logical id of source `i`.
    pub fn source_lid(&self, i: usize) -> u32 {
        i as u32
    }

    /// Logical id of source `i`'s outgoing link.
    pub fn link_lid(&self, i: usize) -> u32 {
        (self.n + i) as u32
    }

    /// Logical id of processor `j`.
    pub fn processor_lid(&self, j: usize) -> u32 {
        (2 * self.n + j) as u32
    }

    /// Realized makespan: the latest compute completion.
    pub fn makespan(&self) -> f64 {
        self.compute_done.iter().fold(0.0f64, |acc, &x| acc.max(x))
    }
}

/// Source component: issues this source's sends in processor order.
#[derive(Debug)]
pub struct Source {
    lid: u32,
    i: usize,
    want: Option<Time>,
}

impl Source {
    /// Source `i` of `world`; first wants to tick at its release time.
    pub fn new(world: &World, i: usize) -> Source {
        Source { lid: world.source_lid(i), i, want: Some(world.release[i]) }
    }
}

impl Component for Source {
    fn next_tick(&self) -> Option<Time> {
        self.want
    }

    fn tick(&mut self, now: Time, ctx: &mut Ctx) {
        self.want = None;
        let i = self.i;
        let m = ctx.world.m;
        if ctx.world.link_dest[i].is_some() {
            return; // mid-send; the link wakes us on completion
        }
        let j = ctx.world.next_j[i];
        if j >= m {
            return; // all fractions delivered
        }
        if ctx.world.proc_expect[j] != i {
            return; // P_j still receiving an earlier source
        }
        let k = i * m + j;
        let mut start = ctx.world.src_free_at[i].max(ctx.world.proc_recv_free_at[j]);
        if let Some(gates) = &ctx.world.gate_send {
            start = start.max(gates[k]);
        }
        if start > now {
            ctx.wake(self.lid, start); // gated into the future
            return;
        }
        let dur = ctx.world.beta[k] * ctx.world.g[i] * ctx.world.link_factor[k];
        let done = finish_with_windows(
            &ctx.world.link_profile[i],
            &ctx.world.recv_windows[j],
            start,
            dur,
        );
        assert!(done.is_finite(), "transfer (S{}, P{}) never completes", i + 1, j + 1);
        ctx.world.send_start[k] = start;
        if let Some(tr) = ctx.world.trace.as_mut() {
            tr.push(start, TraceKind::SendStart, i, j);
        }
        ctx.world.link_dest[i] = Some(j);
        ctx.world.link_done_at[i] = done;
        let link = ctx.world.link_lid(i);
        ctx.wake(link, done);
    }
}

/// Link component: completes this source's in-flight transfer and
/// unblocks whoever was waiting on it.
#[derive(Debug)]
pub struct Link {
    i: usize,
}

impl Link {
    /// Source `i`'s outgoing link.
    pub fn new(i: usize) -> Link {
        Link { i }
    }
}

impl Component for Link {
    fn next_tick(&self) -> Option<Time> {
        None // purely wake-driven
    }

    fn tick(&mut self, now: Time, ctx: &mut Ctx) {
        let i = self.i;
        let j = match ctx.world.link_dest[i] {
            Some(j) => j,
            None => return,
        };
        if ctx.world.link_done_at[i] > now {
            return; // spurious early tick
        }
        let k = i * ctx.world.m + j;
        ctx.world.send_done[k] = now;
        if let Some(tr) = ctx.world.trace.as_mut() {
            tr.push(now, TraceKind::SendComplete, i, j);
        }
        ctx.world.src_free_at[i] = now;
        ctx.world.proc_recv_free_at[j] = now;
        ctx.world.next_j[i] += 1;
        ctx.world.proc_expect[j] += 1;
        ctx.world.link_dest[i] = None;
        // Unblock: the sender (next fraction), the source now expected
        // at P_j (it may have been waiting its turn), and P_j itself
        // (new data to ingest).
        let src = ctx.world.source_lid(i);
        ctx.wake(src, now);
        let expect = ctx.world.proc_expect[j];
        if expect < ctx.world.n {
            let waiting = ctx.world.source_lid(expect);
            ctx.wake(waiting, now);
        }
        let proc = ctx.world.processor_lid(j);
        ctx.wake(proc, now);
    }
}

/// Processor component: ingests arrivals in source order and evaluates
/// its compute timeline through the injected outage windows.
#[derive(Debug)]
pub struct Processor {
    lid: u32,
    j: usize,
    started: bool,
    pipe_end: Time,
    arrivals_seen: usize,
    done_at: Option<Time>,
    finished: bool,
}

impl Processor {
    /// Processor `j` of `world`.
    pub fn new(world: &World, j: usize) -> Processor {
        Processor {
            lid: world.processor_lid(j),
            j,
            started: false,
            pipe_end: 0.0,
            arrivals_seen: 0,
            done_at: None,
            finished: false,
        }
    }
}

impl Component for Processor {
    fn next_tick(&self) -> Option<Time> {
        None // purely wake-driven
    }

    fn tick(&mut self, now: Time, ctx: &mut Ctx) {
        let j = self.j;
        let n = ctx.world.n;
        let m = ctx.world.m;
        // Ingest fractions delivered since the last tick, straight from
        // the world arrays (no arrival queue to allocate).
        while self.arrivals_seen < ctx.world.proc_expect[j] {
            let i = self.arrivals_seen;
            self.arrivals_seen += 1;
            let k = i * m + j;
            if ctx.world.model == TimingModel::FrontEnd {
                let load = ctx.world.beta[k];
                if load > 0.0 {
                    let arrival_began = ctx.world.send_start[k];
                    if !self.started {
                        self.started = true;
                        self.pipe_end = arrival_began;
                        if let Some(tr) = ctx.world.trace.as_mut() {
                            tr.push(arrival_began, TraceKind::ComputeStart, usize::MAX, j);
                        }
                    }
                    // Streaming rule: the pipeline resumes at
                    // max(pipe end, arrival start), burns the chunk
                    // (suspended across outages), and cannot finish
                    // before the data finished arriving.
                    let resume = self.pipe_end.max(arrival_began);
                    let burn = load * ctx.world.a[j] * ctx.world.comp_factor[j];
                    let fin = finish_with_windows(
                        &ctx.world.nominal,
                        &ctx.world.compute_windows[j],
                        resume,
                        burn,
                    );
                    self.pipe_end = fin.max(ctx.world.send_done[k]);
                }
            }
            if self.arrivals_seen == n {
                let done = if ctx.world.model == TimingModel::FrontEnd {
                    self.pipe_end
                } else {
                    // No front-end: all data is here; compute starts now.
                    let total: f64 = (0..n).map(|s| ctx.world.beta[s * m + j]).sum();
                    if let Some(tr) = ctx.world.trace.as_mut() {
                        tr.push(now, TraceKind::ComputeStart, usize::MAX, j);
                    }
                    let burn = total * ctx.world.a[j] * ctx.world.comp_factor[j];
                    finish_with_windows(
                        &ctx.world.nominal,
                        &ctx.world.compute_windows[j],
                        now,
                        burn,
                    )
                };
                assert!(done.is_finite(), "P{} compute never completes", j + 1);
                self.done_at = Some(done);
                ctx.world.compute_done[j] = done;
                ctx.wake(self.lid, done);
            }
        }
        if let Some(done) = self.done_at {
            if !self.finished && done <= now {
                self.finished = true;
                if let Some(tr) = ctx.world.trace.as_mut() {
                    tr.push(done, TraceKind::ComputeComplete, usize::MAX, j);
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec2x3() -> SystemSpec {
        SystemSpec::builder()
            .source(0.2, 0.0)
            .source(0.2, 5.0)
            .processors(&[2.0, 3.0, 4.0])
            .job(100.0)
            .build()
            .unwrap()
    }

    #[test]
    fn world_layout_and_lids() {
        let spec = spec2x3();
        let beta = vec![10.0; 6];
        let w = World::new(&spec, &beta, TimingModel::NoFrontEnd);
        assert_eq!(w.component_count(), 7);
        assert_eq!(w.source_lid(1), 1);
        assert_eq!(w.link_lid(0), 2);
        assert_eq!(w.processor_lid(2), 6);
        assert_eq!(w.src_free_at, vec![0.0, 5.0]);
        assert_eq!(w.makespan(), 0.0);
    }

    #[test]
    #[should_panic(expected = "beta shape mismatch")]
    fn world_rejects_bad_beta_shape() {
        let spec = spec2x3();
        World::new(&spec, &[1.0; 5], TimingModel::NoFrontEnd);
    }
}
