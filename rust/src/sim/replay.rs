//! Replay LP schedules through the cluster engine and report
//! predicted-vs-simulated divergence.
//!
//! This is the end-to-end correctness oracle the paper never had: take
//! a solved schedule (β matrix + the LP's promised `T_f`), execute it
//! operationally in [`crate::sim::cluster`] — optionally under faults,
//! preemption, link slowdowns and jitter — and compare what actually
//! happened against what the LP predicted. The resulting
//! [`DivergenceReport`] travels on the wire as `diagnostics.sim` and
//! is reachable via `dlt simulate`.
//!
//! Two gating modes control how literally the LP's timeline is
//! followed:
//!
//! - [`Gate::Schedule`] (default): sends may not start before the LP's
//!   `TS_{i,j}`. Because the LP's windows are feasible (≥ ASAP), this
//!   reproduces the LP's own timeline — a jitter-free, fault-free
//!   replay must match `T_f` to fp accuracy, which is exactly the
//!   divergence-oracle claim worth testing.
//! - [`Gate::Asap`]: ignore the LP's timing and close every gap
//!   greedily — bit-compatible with the legacy [`crate::sim::engine`]
//!   and never slower than the gated replay.

use crate::dlt::schedule::{Schedule, TimingModel};
use crate::error::{Error, Result};
use crate::model::SystemSpec;
use crate::pipeline::Solved;
use crate::sim::cluster::{ClusterSim, InjectionPlan, World};
use crate::sim::jitter;
use crate::sim::trace::{Trace, TraceKind};

/// How send start times are bounded during replay.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Gate {
    /// Lower-bound each send at the LP's `TS_{i,j}` (follow the LP's
    /// timeline).
    #[default]
    Schedule,
    /// Ignore the LP's timing; start every send as soon as possible
    /// (legacy-engine semantics).
    Asap,
}

/// Replay configuration.
#[derive(Debug, Clone, Default)]
pub struct ReplayOptions {
    /// Send-gating mode.
    pub gate: Gate,
    /// Multiplicative jitter amplitude on per-fraction link times
    /// (uniform in `[1−j, 1+j]`, shape-stable per cell). 0 disables.
    pub link_jitter: f64,
    /// Multiplicative jitter amplitude on per-processor compute times.
    pub compute_jitter: f64,
    /// Seed for jitter and seeded-random faults.
    pub seed: u64,
    /// Faults, preemptions and link windows to inject.
    pub plan: InjectionPlan,
    /// Record a trace (allocates; leave off for allocation-audited
    /// runs).
    pub trace: bool,
}

/// Predicted-vs-simulated comparison for one replay.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct DivergenceReport {
    /// The LP's promised makespan `T_f`.
    pub predicted_makespan: f64,
    /// Makespan realized by the cluster engine.
    pub simulated_makespan: f64,
    /// `(simulated − predicted) / predicted` (positive = the system
    /// ran late).
    pub rel_gap: f64,
    /// `predicted − compute_done[j]` per processor (negative = that
    /// processor finished after the predicted makespan).
    pub per_processor_slack: Vec<f64>,
    /// LP promises the simulated execution broke (empty when the
    /// schedule replayed cleanly).
    pub violated_constraints: Vec<String>,
    /// Engine ticks processed.
    pub events: u64,
    /// Tick-queue high-water mark.
    pub max_queue_depth: usize,
    /// Fail/restart outages injected (scheduled + seeded-random).
    pub faults_injected: usize,
    /// Preemption windows injected.
    pub preemptions: usize,
    /// Execution trace with injection markers, when requested (not
    /// serialized on the wire).
    pub trace: Option<Trace>,
}

/// Replay `sched` for `spec` through the cluster engine.
pub fn replay(
    spec: &SystemSpec,
    sched: &Schedule,
    opts: &ReplayOptions,
) -> Result<DivergenceReport> {
    let n = spec.n();
    let m = spec.m();
    if sched.n != n || sched.m != m || sched.beta.len() != n * m {
        return Err(Error::InvalidSchedule(format!(
            "schedule shape {}x{} does not match spec {n}x{m}",
            sched.n,
            sched.m
        )));
    }
    let predicted = sched.makespan;
    let horizon = predicted.max(sched.realized_makespan());
    let resolved = opts.plan.resolve(n, m, horizon, opts.seed)?;

    let mut world = World::new(spec, &sched.beta, sched.model);
    for i in 0..n {
        for j in 0..m {
            world.link_factor[i * m + j] = jitter::link_factor(opts.seed, opts.link_jitter, i, j);
        }
    }
    for j in 0..m {
        world.comp_factor[j] = jitter::compute_factor(opts.seed, opts.compute_jitter, j);
    }
    world.link_profile = resolved.link_profiles.clone();
    world.compute_windows = resolved.compute_windows.clone();
    world.recv_windows = resolved.recv_windows.clone();
    if opts.gate == Gate::Schedule {
        world.gate_send = Some(sched.comm_start.clone());
    }
    if opts.trace {
        world.trace = Some(Trace::default());
    }

    let mut sim = ClusterSim::new(world);
    sim.run();
    let stats = sim.stats();
    let world = sim.into_world();

    let simulated = world.makespan();
    let rel_gap = (simulated - predicted) / predicted.abs().max(1e-12);
    let per_processor_slack: Vec<f64> = world.compute_done.iter().map(|&d| predicted - d).collect();

    let mut violated = Vec::new();
    let r = spec.releases();
    for j in 0..m {
        let d = world.compute_done[j];
        if !d.is_finite() {
            violated.push(format!("P{} never finished computing", j + 1));
        } else if d > predicted * (1.0 + 1e-9) + 1e-9 {
            violated.push(format!(
                "P{} finished at {:.6}, after the predicted T_f {:.6}",
                j + 1,
                d,
                predicted
            ));
        }
    }
    for i in 0..n {
        if world.send_start[i * m] < r[i] - 1e-9 {
            violated.push(format!("S{} started sending before its release time", i + 1));
        }
        for j in 0..m.saturating_sub(1) {
            if world.send_done[i * m + j] > world.send_start[i * m + j + 1] + 1e-9 {
                violated.push(format!("S{} overlapped sends to P{} and P{}", i + 1, j + 1, j + 2));
            }
        }
    }
    for j in 0..m {
        for i in 0..n.saturating_sub(1) {
            if world.send_done[i * m + j] > world.send_start[(i + 1) * m + j] + 1e-9 {
                violated.push(format!(
                    "P{} received from S{} and S{} concurrently",
                    j + 1,
                    i + 1,
                    i + 2
                ));
            }
        }
    }

    let trace = world.trace.map(|mut tr| {
        // Injection markers: a compute window that exactly matches a
        // receive-blocking window is a fail/restart; anything else is
        // preemption (possibly merged with one).
        for j in 0..m {
            for &(from, to, _) in &world.recv_windows[j] {
                tr.push(from, TraceKind::Fail, usize::MAX, j);
                tr.push(to, TraceKind::Restart, usize::MAX, j);
            }
            for &(from, to, _) in &world.compute_windows[j] {
                if !world.recv_windows[j].contains(&(from, to, false)) {
                    tr.push(from, TraceKind::PreemptStart, usize::MAX, j);
                    tr.push(to, TraceKind::PreemptEnd, usize::MAX, j);
                }
            }
        }
        tr.events.sort_by(|x, y| x.time.partial_cmp(&y.time).unwrap());
        tr
    });

    Ok(DivergenceReport {
        predicted_makespan: predicted,
        simulated_makespan: simulated,
        rel_gap,
        per_processor_slack,
        violated_constraints: violated,
        events: stats.events,
        max_queue_depth: stats.queue_high_water,
        faults_injected: resolved.faults_injected,
        preemptions: resolved.preemptions,
        trace,
    })
}

/// Replay a [`crate::pipeline::Solved`] (the β matrix + `T_f` the
/// pipeline produced) through the cluster engine.
pub fn replay_solved(
    spec: &SystemSpec,
    solved: &Solved,
    opts: &ReplayOptions,
) -> Result<DivergenceReport> {
    replay(spec, &solved.schedule, opts)
}

/// Build a synthetic `m`-processor topology (plus a consistent
/// schedule) for scale experiments, without solving an LP of that
/// size: sources are copied from `base`, processors get ascending
/// inverse speeds `A_k = 1 + 10⁻³·k`, load shares are proportional to
/// `1/G_i × 1/A_j`, and the schedule's timing — including its
/// `makespan` — is stamped from one nominal ASAP replay, so a
/// jitter-free fault-free replay reproduces it *exactly* (rel gap
/// `0.0`).
pub fn synthetic_scale(
    base: &SystemSpec,
    m: usize,
    model: TimingModel,
) -> Result<(SystemSpec, Schedule)> {
    if m == 0 {
        return Err(Error::Usage("synthetic scale needs at least 1 processor".into()));
    }
    let mut b = SystemSpec::builder();
    for s in &base.sources {
        b = b.source(s.g, s.release);
    }
    let a: Vec<f64> = (0..m).map(|k| 1.0 + 1e-3 * k as f64).collect();
    let spec = b.processors(&a).job(base.job).build()?;

    let n = spec.n();
    let g = spec.g();
    let src_w: Vec<f64> = g.iter().map(|&gi| 1.0 / gi).collect();
    let src_total: f64 = src_w.iter().sum();
    let proc_w: Vec<f64> = a.iter().map(|&aj| 1.0 / aj).collect();
    let proc_total: f64 = proc_w.iter().sum();
    let mut beta = vec![0.0; n * m];
    for i in 0..n {
        for j in 0..m {
            beta[i * m + j] = spec.job * (src_w[i] / src_total) * (proc_w[j] / proc_total);
        }
    }

    let (comm_start, comm_end) = crate::dlt::frontend::reconstruct_comm_windows(&spec, &beta);

    // Ground-truth timing from one nominal ASAP execution.
    let mut sim = ClusterSim::new(World::new(&spec, &beta, model));
    sim.run();
    let world = sim.into_world();

    let mut compute_start = vec![0.0; m];
    for j in 0..m {
        compute_start[j] = match model {
            TimingModel::NoFrontEnd => comm_end[(n - 1) * m + j],
            TimingModel::FrontEnd => (0..n)
                .find(|&i| beta[i * m + j] > 0.0)
                .map(|i| comm_start[i * m + j])
                .unwrap_or(0.0),
        };
    }
    let makespan = world.makespan();
    let sched = Schedule {
        n,
        m,
        model,
        beta,
        comm_start,
        comm_end,
        compute_start,
        compute_end: world.compute_done,
        makespan,
        lp_iterations: 0,
    };
    Ok((spec, sched))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dlt::frontend::FeOptions;
    use crate::dlt::no_frontend::NfeOptions;
    use crate::sim::cluster::FaultSpec;

    fn table2_spec() -> SystemSpec {
        SystemSpec::builder()
            .source(0.2, 0.0)
            .source(0.2, 5.0)
            .processors(&[2.0, 3.0, 4.0])
            .job(100.0)
            .build()
            .unwrap()
    }

    #[test]
    fn gated_replay_reproduces_lp_makespan() {
        let spec = table2_spec();
        let sched = crate::pipeline::solve(&NfeOptions::default(), &spec).unwrap();
        let rep = replay(&spec, &sched, &ReplayOptions::default()).unwrap();
        assert!(
            rep.rel_gap.abs() <= 1e-9,
            "rel gap {} (sim {} vs LP {})",
            rep.rel_gap,
            rep.simulated_makespan,
            rep.predicted_makespan
        );
        assert!(rep.violated_constraints.is_empty(), "{:?}", rep.violated_constraints);
        assert!(rep.events > 0);
        assert_eq!(rep.per_processor_slack.len(), 3);
    }

    #[test]
    fn asap_replay_only_matches_or_beats() {
        let spec = table2_spec();
        let sched = crate::pipeline::solve(&FeOptions::default(), &spec).unwrap();
        let opts = ReplayOptions { gate: Gate::Asap, ..Default::default() };
        let rep = replay(&spec, &sched, &opts).unwrap();
        assert!(rep.simulated_makespan <= rep.predicted_makespan + 1e-6);
    }

    #[test]
    fn fault_delays_and_is_reported() {
        let spec = table2_spec();
        let sched = crate::pipeline::solve(&NfeOptions::default(), &spec).unwrap();
        let clean = replay(&spec, &sched, &ReplayOptions::default()).unwrap();
        let opts = ReplayOptions {
            plan: InjectionPlan {
                faults: vec![FaultSpec::parse_fail("p1@1.0+5.0").unwrap()],
                ..Default::default()
            },
            ..Default::default()
        };
        let rep = replay(&spec, &sched, &opts).unwrap();
        assert_eq!(rep.faults_injected, 1);
        assert!(rep.simulated_makespan > clean.simulated_makespan);
        assert!(rep.rel_gap > 0.0);
        assert!(
            rep.violated_constraints.iter().any(|v| v.contains("after the predicted")),
            "{:?}",
            rep.violated_constraints
        );
        // Slack for the failed processor went negative.
        assert!(rep.per_processor_slack[0] < 0.0);
    }

    #[test]
    fn trace_carries_injection_markers() {
        let spec = table2_spec();
        let sched = crate::pipeline::solve(&NfeOptions::default(), &spec).unwrap();
        let opts = ReplayOptions {
            trace: true,
            plan: InjectionPlan {
                faults: vec![
                    FaultSpec::parse_fail("p1@1.0+2.0").unwrap(),
                    FaultSpec::parse_preempt("p2@1.0+0.5").unwrap(),
                ],
                ..Default::default()
            },
            ..Default::default()
        };
        let rep = replay(&spec, &sched, &opts).unwrap();
        let tr = rep.trace.unwrap();
        assert!(tr.events.iter().any(|e| e.kind == TraceKind::Fail));
        assert!(tr.events.iter().any(|e| e.kind == TraceKind::Restart));
        assert!(tr.events.iter().any(|e| e.kind == TraceKind::PreemptStart));
        assert!(tr.events.windows(2).all(|w| w[0].time <= w[1].time), "trace sorted");
    }

    #[test]
    fn synthetic_scale_is_exactly_reproducible() {
        let base = table2_spec();
        for model in [TimingModel::NoFrontEnd, TimingModel::FrontEnd] {
            let (spec, sched) = synthetic_scale(&base, 64, model).unwrap();
            assert_eq!(spec.m(), 64);
            let rep = replay(&spec, &sched, &ReplayOptions::default()).unwrap();
            assert_eq!(rep.rel_gap, 0.0, "model {model:?}: gap {}", rep.rel_gap);
            assert!(rep.violated_constraints.is_empty(), "{:?}", rep.violated_constraints);
        }
    }

    #[test]
    fn shape_mismatch_is_rejected() {
        let spec = table2_spec();
        let mut sched = crate::pipeline::solve(&NfeOptions::default(), &spec).unwrap();
        sched.m = 2;
        assert!(replay(&spec, &sched, &ReplayOptions::default()).is_err());
    }
}
