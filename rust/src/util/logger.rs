//! Minimal `log` facade backend (stderr, level from `DLT_LOG`).
//!
//! The vendored `log` crate is built without its `std` feature, so a
//! `&'static` logger with an atomic level is used instead of
//! `set_boxed_logger`.

use log::{Level, LevelFilter, Log, Metadata, Record};
use std::io::Write;
use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::Once;

static LEVEL: AtomicU8 = AtomicU8::new(2); // warn

fn level() -> Level {
    match LEVEL.load(Ordering::Relaxed) {
        1 => Level::Error,
        2 => Level::Warn,
        3 => Level::Info,
        4 => Level::Debug,
        _ => Level::Trace,
    }
}

struct StderrLogger;

impl Log for StderrLogger {
    fn enabled(&self, metadata: &Metadata) -> bool {
        metadata.level() <= level()
    }

    fn log(&self, record: &Record) {
        if !self.enabled(record.metadata()) {
            return;
        }
        let mut err = std::io::stderr().lock();
        let _ = writeln!(err, "[{:5} {}] {}", record.level(), record.target(), record.args());
    }

    fn flush(&self) {}
}

static LOGGER: StderrLogger = StderrLogger;
static INIT: Once = Once::new();

/// Initialize logging once. Level comes from `DLT_LOG`
/// (`error|warn|info|debug|trace`, default `warn`). Safe to call many
/// times; only the first call installs the logger.
pub fn init() {
    INIT.call_once(|| {
        let lvl = match std::env::var("DLT_LOG").as_deref() {
            Ok("error") => 1,
            Ok("info") => 3,
            Ok("debug") => 4,
            Ok("trace") => 5,
            _ => 2,
        };
        LEVEL.store(lvl, Ordering::Relaxed);
        if log::set_logger(&LOGGER).is_ok() {
            log::set_max_level(level().to_level_filter().min(LevelFilter::Trace));
        }
    });
}

#[cfg(test)]
mod tests {
    #[test]
    fn init_idempotent() {
        super::init();
        super::init();
        log::warn!("logger smoke test");
    }
}
