//! Deterministic pseudo-random number generation.
//!
//! Two small, well-known generators:
//! - [`SplitMix64`] — used for seeding and cheap streams.
//! - [`Pcg32`] — PCG-XSH-RR 64/32, the default generator for tests,
//!   workload generation and the property-test harness.
//!
//! Both are fully deterministic from their seed, which keeps every
//! experiment in the repo reproducible.

/// Minimal RNG interface (the subset of `rand::Rng` this crate needs).
pub trait Rng {
    /// Next 64 random bits.
    fn next_u64(&mut self) -> u64;

    /// Next 32 random bits.
    fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform `f64` in `[0, 1)`.
    fn f64(&mut self) -> f64 {
        // 53 random mantissa bits.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform `f64` in `[lo, hi)`.
    fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Uniform `usize` in `[0, n)`. `n` must be > 0.
    fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // Lemire-style unbiased bounded sampling on 64 bits.
        let n = n as u64;
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut lo = m as u64;
        if lo < n {
            let t = n.wrapping_neg() % n;
            while lo < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                lo = m as u64;
            }
        }
        (m >> 64) as usize
    }

    /// Uniform `usize` in `[lo, hi)`.
    fn range_usize(&mut self, lo: usize, hi: usize) -> usize {
        debug_assert!(hi > lo);
        lo + self.below(hi - lo)
    }

    /// Random boolean with probability `p` of `true`.
    fn bool_with(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Fisher–Yates shuffle.
    fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Pick a uniformly random element.
    fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len())]
    }
}

/// SplitMix64 — tiny, fast, passes BigCrush; ideal seeder.
#[derive(Debug, Clone)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    /// Create from a seed.
    pub fn new(seed: u64) -> Self {
        SplitMix64 { state: seed }
    }
}

impl Rng for SplitMix64 {
    fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }
}

/// PCG-XSH-RR 64/32: 64-bit LCG state, 32-bit output with rotation.
#[derive(Debug, Clone)]
pub struct Pcg32 {
    state: u64,
    inc: u64,
}

const PCG_MULT: u64 = 6364136223846793005;

impl Pcg32 {
    /// Create from a seed (stream constant fixed).
    pub fn new(seed: u64) -> Self {
        Self::with_stream(seed, 0xDA3E39CB94B95BDB)
    }

    /// Create with an explicit stream selector (must be odd after shift).
    pub fn with_stream(seed: u64, stream: u64) -> Self {
        let inc = (stream << 1) | 1;
        let mut rng = Pcg32 { state: 0, inc };
        rng.step();
        rng.state = rng.state.wrapping_add(seed);
        rng.step();
        rng
    }

    fn step(&mut self) {
        self.state = self.state.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
    }

    fn output(state: u64) -> u32 {
        let xorshifted = (((state >> 18) ^ state) >> 27) as u32;
        let rot = (state >> 59) as u32;
        xorshifted.rotate_right(rot)
    }
}

impl Rng for Pcg32 {
    fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.step();
        Self::output(old)
    }

    fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_deterministic() {
        let mut a = SplitMix64::new(42);
        let mut b = SplitMix64::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn splitmix_seed_sensitivity() {
        let mut a = SplitMix64::new(1);
        let mut b = SplitMix64::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn pcg_deterministic() {
        let mut a = Pcg32::new(7);
        let mut b = Pcg32::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut rng = Pcg32::new(3);
        for _ in 0..10_000 {
            let x = rng.f64();
            assert!((0.0..1.0).contains(&x), "{x}");
        }
    }

    #[test]
    fn f64_roughly_uniform() {
        let mut rng = Pcg32::new(11);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| rng.f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn below_bounds_and_coverage() {
        let mut rng = Pcg32::new(5);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let k = rng.below(10);
            assert!(k < 10);
            seen[k] = true;
        }
        assert!(seen.iter().all(|&s| s), "all buckets hit");
    }

    #[test]
    fn below_unbiased_small_n() {
        // n=3: counts should be within 5% of each other over 90k draws.
        let mut rng = Pcg32::new(17);
        let mut counts = [0usize; 3];
        for _ in 0..90_000 {
            counts[rng.below(3)] += 1;
        }
        for &c in &counts {
            assert!((c as f64 - 30_000.0).abs() < 1_500.0, "{counts:?}");
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Pcg32::new(23);
        let mut xs: Vec<u32> = (0..50).collect();
        rng.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn range_f64_bounds() {
        let mut rng = Pcg32::new(31);
        for _ in 0..1000 {
            let x = rng.range_f64(-2.5, 7.5);
            assert!((-2.5..7.5).contains(&x));
        }
    }
}
