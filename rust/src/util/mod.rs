//! Numeric and infrastructure utilities: PRNG, statistics, float
//! comparison, logging.
//!
//! The offline crate set for this build has no `rand`, `approx` or
//! `env_logger`, so these are small from-scratch implementations with
//! interfaces mirroring the familiar crates.

pub mod float;
pub mod logger;
pub mod rng;
pub mod stats;

pub use float::{approx_eq, approx_eq_eps, relative_diff};
pub use rng::{Pcg32, Rng, SplitMix64};
pub use stats::{OnlineStats, Summary};
