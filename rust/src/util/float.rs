//! Floating-point comparison helpers.
//!
//! Scheduling quantities in this crate span roughly 1e-6 .. 1e5, so the
//! default comparison is *relative* with an absolute floor.

/// Default relative tolerance used across the crate's checks.
pub const DEFAULT_REL_TOL: f64 = 1e-9;
/// Default absolute floor (values below this are "equal to zero").
pub const DEFAULT_ABS_TOL: f64 = 1e-9;

/// Relative difference `|a-b| / max(|a|, |b|, 1)`.
pub fn relative_diff(a: f64, b: f64) -> f64 {
    let scale = a.abs().max(b.abs()).max(1.0);
    (a - b).abs() / scale
}

/// Approximate equality with the crate default tolerances.
pub fn approx_eq(a: f64, b: f64) -> bool {
    approx_eq_eps(a, b, DEFAULT_REL_TOL, DEFAULT_ABS_TOL)
}

/// Approximate equality with explicit relative/absolute tolerances.
pub fn approx_eq_eps(a: f64, b: f64, rel: f64, abs: f64) -> bool {
    let d = (a - b).abs();
    if d <= abs {
        return true;
    }
    d <= rel * a.abs().max(b.abs())
}

/// `a <= b` up to tolerance (used by schedule validators).
pub fn leq_eps(a: f64, b: f64, eps: f64) -> bool {
    a <= b + eps
}

/// Clamp tiny negatives (LP roundoff) to zero; leave other values alone.
pub fn snap_nonneg(x: f64, eps: f64) -> f64 {
    if x < 0.0 && x > -eps {
        0.0
    } else {
        x
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn approx_eq_basic() {
        assert!(approx_eq(1.0, 1.0 + 1e-12));
        assert!(!approx_eq(1.0, 1.001));
        assert!(approx_eq(0.0, 1e-12));
        assert!(approx_eq(1e9, 1e9 * (1.0 + 1e-10)));
    }

    #[test]
    fn leq_with_tolerance() {
        assert!(leq_eps(1.0, 1.0 - 1e-12, 1e-9));
        assert!(!leq_eps(1.0, 0.9, 1e-9));
    }

    #[test]
    fn snap_behavior() {
        assert_eq!(snap_nonneg(-1e-12, 1e-9), 0.0);
        assert_eq!(snap_nonneg(-1.0, 1e-9), -1.0);
        assert_eq!(snap_nonneg(2.0, 1e-9), 2.0);
    }

    #[test]
    fn relative_diff_scales() {
        assert!(relative_diff(1000.0, 1001.0) < 2e-3);
        assert!(relative_diff(0.0, 0.0) == 0.0);
    }
}
