//! Descriptive statistics used by the bench harness and metrics.

/// Streaming mean/variance via Welford's algorithm, plus min/max.
#[derive(Debug, Clone, Default)]
pub struct OnlineStats {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl OnlineStats {
    /// Empty accumulator.
    pub fn new() -> Self {
        OnlineStats { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    /// Add one observation.
    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    /// Number of observations.
    pub fn count(&self) -> u64 {
        self.n
    }

    /// Arithmetic mean (0 if empty).
    pub fn mean(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.mean
        }
    }

    /// Sample variance (n-1 denominator).
    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    /// Sample standard deviation.
    pub fn stddev(&self) -> f64 {
        self.variance().sqrt()
    }

    /// Minimum observation.
    pub fn min(&self) -> f64 {
        self.min
    }

    /// Maximum observation.
    pub fn max(&self) -> f64 {
        self.max
    }
}

/// Five-number-style summary of a sample, computed by sorting a copy.
#[derive(Debug, Clone, PartialEq)]
pub struct Summary {
    /// Number of samples.
    pub n: usize,
    /// Arithmetic mean.
    pub mean: f64,
    /// Sample standard deviation.
    pub stddev: f64,
    /// Minimum.
    pub min: f64,
    /// Median (p50).
    pub median: f64,
    /// 95th percentile.
    pub p95: f64,
    /// 99th percentile.
    pub p99: f64,
    /// Maximum.
    pub max: f64,
}

impl Summary {
    /// Summarize a sample. NaN observations (e.g. a failed timing read)
    /// are dropped rather than poisoning the sort; `n` counts only the
    /// finite-ordered samples kept. Returns a zeroed summary when no
    /// samples survive.
    pub fn of(samples: &[f64]) -> Summary {
        let mut sorted: Vec<f64> = samples.iter().copied().filter(|x| !x.is_nan()).collect();
        if sorted.is_empty() {
            return Summary { n: 0, mean: 0.0, stddev: 0.0, min: 0.0, median: 0.0, p95: 0.0, p99: 0.0, max: 0.0 };
        }
        sorted.sort_by(|a, b| a.total_cmp(b));
        let mut acc = OnlineStats::new();
        for &x in &sorted {
            acc.push(x);
        }
        Summary {
            n: sorted.len(),
            mean: acc.mean(),
            stddev: acc.stddev(),
            min: sorted[0],
            median: percentile_sorted(&sorted, 0.50),
            p95: percentile_sorted(&sorted, 0.95),
            p99: percentile_sorted(&sorted, 0.99),
            max: *sorted.last().unwrap(),
        }
    }
}

/// Linear-interpolated percentile of an already-sorted sample.
pub fn percentile_sorted(sorted: &[f64], q: f64) -> f64 {
    assert!(!sorted.is_empty());
    assert!((0.0..=1.0).contains(&q));
    if sorted.len() == 1 {
        return sorted[0];
    }
    let pos = q * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    let frac = pos - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi] * frac
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn online_mean_var() {
        let mut s = OnlineStats::new();
        for x in [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0] {
            s.push(x);
        }
        assert!((s.mean() - 5.0).abs() < 1e-12);
        // population variance is 4.0 -> sample variance 32/7
        assert!((s.variance() - 32.0 / 7.0).abs() < 1e-12);
        assert_eq!(s.min(), 2.0);
        assert_eq!(s.max(), 9.0);
    }

    #[test]
    fn online_empty() {
        let s = OnlineStats::new();
        assert_eq!(s.mean(), 0.0);
        assert_eq!(s.variance(), 0.0);
        assert_eq!(s.count(), 0);
    }

    #[test]
    fn percentiles() {
        let sorted: Vec<f64> = (1..=100).map(|x| x as f64).collect();
        assert!((percentile_sorted(&sorted, 0.0) - 1.0).abs() < 1e-12);
        assert!((percentile_sorted(&sorted, 1.0) - 100.0).abs() < 1e-12);
        assert!((percentile_sorted(&sorted, 0.5) - 50.5).abs() < 1e-12);
    }

    #[test]
    fn summary_median_even_odd() {
        let s = Summary::of(&[1.0, 3.0, 2.0]);
        assert_eq!(s.median, 2.0);
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0]);
        assert!((s.median - 2.5).abs() < 1e-12);
    }

    #[test]
    fn summary_drops_nan_instead_of_panicking() {
        let s = Summary::of(&[1.0, f64::NAN, 3.0]);
        assert_eq!(s.n, 2);
        assert!((s.median - 2.0).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 3.0);
        let s = Summary::of(&[f64::NAN, f64::NAN]);
        assert_eq!(s.n, 0);
        assert_eq!(s.mean, 0.0);
        assert_eq!(s.max, 0.0);
    }

    #[test]
    fn summary_single() {
        let s = Summary::of(&[5.0]);
        assert_eq!(s.mean, 5.0);
        assert_eq!(s.median, 5.0);
        assert_eq!(s.p99, 5.0);
        assert_eq!(s.stddev, 0.0);
    }
}
