//! Command-line interface (from-scratch arg parsing — no `clap` in the
//! offline crate set).
//!
//! ```text
//! dlt solve     --spec spec.json [--model fe|nfe] [--solver simplex|pdhg|pdhg-artifact]
//!               [--factorization product_form_eta|forrest_tomlin|markowitz|bartels_golub]
//!               [--pricing dantzig|devex|steepest_edge] [--timeout-ms MS]
//! dlt batch     [--requests FILE|-] [--backend NAME]
//!               [--factorization NAME] [--pricing NAME]
//!               [--threads T] [--pretty]
//! dlt simulate  --spec spec.json [--model fe|nfe] [--engine cluster|legacy]
//!               [--jitter 0.1] [--seed 7] [--trace] [--asap] [--json]
//!               [--fail p3@t=1.5+2] [--preempt p2@4+1.5!redo]
//!               [--link-profile s1@10+5*0.25] [--rand-faults K] [--scale M]
//! dlt cluster   --spec spec.json [--model fe|nfe] [--time-scale 0.002] [--real-compute]
//! dlt tradeoff  --spec spec.json [--budget-cost X] [--budget-time Y] [--gradient 0.06]
//! dlt sweep     --spec spec.json [--param job,procs,release,links] [--from A --to B --points N]
//!               [--release-from A --release-to B --release-points N]
//!               [--link-from A --link-to B --link-points N]
//!               [--threads T] [--cold] [--steal] [--model fe|nfe]
//!               [--backend NAME] [--refine TOL] [--knee-threshold G]
//! dlt speedup   --spec spec.json --sources 1,2,3
//! dlt experiments [--exp fig12] [--csv-dir out/]
//! dlt artifacts
//! dlt serve     [--host 127.0.0.1] [--port 4517] [--workers W] [--shards S]
//!               [--queue-depth Q] [--warm-budget-kb KB] [--retry-after-ms MS]
//!               [--degraded] [--default-timeout-ms MS]
//!               [--backend NAME] [--factorization NAME] [--pricing NAME]
//!               [--max-seconds N]
//! ```

pub mod args;
pub mod commands;

use crate::error::{Error, Result};

/// Run the CLI with raw argv.
pub fn run(argv: &[String]) -> Result<()> {
    let parsed = args::Args::parse(&argv[1..])?;
    match parsed.subcommand.as_str() {
        "solve" => commands::solve(&parsed),
        "batch" => commands::batch(&parsed),
        "simulate" => commands::simulate(&parsed),
        "cluster" => commands::cluster(&parsed),
        "tradeoff" => commands::tradeoff(&parsed),
        "sweep" => commands::sweep_cmd(&parsed),
        "speedup" => commands::speedup_cmd(&parsed),
        "experiments" => commands::experiments(&parsed),
        "artifacts" => commands::artifacts(&parsed),
        "serve" => commands::serve(&parsed),
        "help" | "" => {
            print!("{}", HELP);
            Ok(())
        }
        other => Err(Error::Usage(format!("unknown subcommand `{other}`\n{HELP}"))),
    }
}

/// Top-level help text.
pub const HELP: &str = "\
dlt — multi-source multi-processor divisible-load scheduling
  (reproduction of Cao/Wu/Robertazzi 2019)

USAGE: dlt <subcommand> [flags]

SUBCOMMANDS
  solve        solve one scheduling instance, print the beta table
  batch        solve a JSON array of api requests (file or stdin),
               emit a JSON array of responses — the serving front door
  simulate     replay the solved schedule on a simulator engine
               (component cluster engine with fault injection, or the
               legacy fixed-function replayer)
  cluster      execute the schedule on the threaded cluster runtime
  tradeoff     §6 trade-off advisor (cost/time budgets)
  sweep        solve a scenario grid in parallel with warm-started LPs
  speedup      §5 speedup analysis
  experiments  regenerate the paper's figures (tables / CSV)
  artifacts    inspect the AOT artifact manifest
  serve        TCP serving tier: newline-delimited request/response
               JSON over persistent connections, warm per-client shards
  help         this text

COMMON FLAGS
  --spec FILE        system spec JSON (see config::spec)
  --model fe|nfe     timing model (default fe)
  --solver NAME      simplex | pdhg | pdhg-artifact (default simplex)
  --factorization N  simplex basis-factorization strategy:
                     product_form_eta (default) | forrest_tomlin |
                     markowitz | bartels_golub
  --pricing NAME     simplex pricing rule:
                     dantzig (default) | devex | steepest_edge
  --timeout-ms MS    wall-clock solve deadline; expiry is a typed
                     `deadline exceeded` error, not a partial answer
  --csv-dir DIR      also write CSV output
  --exp NAME         experiment id (fig10..fig20; default: all)

BATCH FLAGS
  --requests FILE    JSON array of api::SolveRequest (default/-: stdin)
  --backend NAME     default backend for requests that do not override:
                     revised_simplex | dense_tableau | pdhg |
                     pdhg_block (alias pdhg-block) | hybrid
  --threads T        batch worker threads (default: one per core)
  --pretty           pretty-print the response array
  (--factorization / --pricing set the session defaults; per-request
   "options" override them)

SIMULATE FLAGS
  --engine E         cluster (component engine, default) | legacy
  --fail LIST        processor outages, comma-separated: p3@t=1.5[+DUR]
                     — in-flight work is lost and redone after restart
  --preempt LIST     compute preemptions: p2@4+1.5[!redo] — compute
                     pauses and resumes (redoes with !redo); receives
                     keep flowing during the window
  --link-profile L   time-varying links: s1@10+5*0.25 scales source 1's
                     outgoing capacity by 0.25 for 5 time units
  --rand-faults K    additionally inject K seeded-random outages
  --scale M          synthetic M-processor topology stamped from the
                     spec's sources (skips the LP solve)
  --asap             greedy replay: ignore the LP send timeline
  --jitter X         multiplicative link + compute noise amplitude
  --json             print the divergence report as JSON
  --trace            print the event trace (cluster: with fault and
                     preemption markers)

SWEEP FLAGS
  --param LIST       comma-separated axes, crossed into one grid:
                     job | procs | release | links   (default job)
  --from A --to B    job-size range (default J .. 5J)
  --points N         job-axis resolution (default 50)
  --release-from A --release-to B --release-points N
                     release-time scale axis (defaults 0 .. 2, 9 points)
  --link-from A --link-to B --link-points N
                     link-speed (G) scale axis (defaults 0.5 .. 2, 9 points)
  --threads T        worker threads (default: one per core)
  --cold             disable basis warm starts (baseline measurement)
  --steal            work-stealing scheduler (best for ragged grids,
                     e.g. any grid with a procs axis)
  --backend NAME     sweep solver backend (see BATCH FLAGS); pdhg_block
                     batches the grid into first-order panels
  --refine TOL       bisect a single continuous axis around the
                     diminishing-returns knee until the bracket width
                     drops below TOL x the coarse interval
  --knee-threshold G relative-improvement-per-unit knee threshold for
                     --refine (default 0.06)

SERVE FLAGS
  --host H           bind address (default 127.0.0.1)
  --port P           bind port (default 4517)
  --workers W        accept/solve threads (default: one per core)
  --shards S         session shards (default: 2 per worker)
  --queue-depth Q    per-shard admission queue depth before requests
                     are shed with an `overloaded` error (default 64)
  --warm-budget-kb K total warm-session byte budget, split across
                     shards, LRU-evicted when exceeded (default 65536)
  --retry-after-ms M base retry hint attached to shed responses,
                     scaled up with the shard queue depth (default 50)
  --degraded         degraded mode: absorb up to one extra queue-depth
                     of overflow with loosened first-order solves
                     flagged `degraded: true` instead of shedding
  --default-timeout-ms MS
                     deadline stamped on requests without their own
                     `timeout_ms` option (0 / absent: unbounded)
  --max-seconds N    serve for N seconds, drain gracefully, print
                     counters and exit (0 / absent: run forever)
  (the {\"reload\": {...}} admin frame swaps queue_depth,
   retry_after_ms, warm_budget_kb, degraded and default_timeout_ms at
   runtime without dropping connections)
  (--backend / --factorization / --pricing set the session defaults;
   per-request \"options\" override them)
";

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &str) -> Vec<String> {
        std::iter::once("dlt".to_string()).chain(s.split_whitespace().map(String::from)).collect()
    }

    #[test]
    fn help_runs() {
        run(&argv("help")).unwrap();
    }

    #[test]
    fn unknown_subcommand_errors() {
        assert!(run(&argv("frobnicate")).is_err());
    }

    #[test]
    fn experiments_single_figure() {
        run(&argv("experiments --exp fig10")).unwrap();
    }

    #[test]
    fn solve_with_inline_spec() {
        let path = "/tmp/dlt_cli_spec.json";
        std::fs::write(
            path,
            r#"{"sources":[{"g":0.2},{"g":0.4,"release":1}],
                "processors":[{"a":2},{"a":3}],"job":10}"#,
        )
        .unwrap();
        run(&argv(&format!("solve --spec {path}"))).unwrap();
        run(&argv(&format!("solve --spec {path} --model nfe"))).unwrap();
        run(&argv(&format!("solve --spec {path} --solver pdhg"))).unwrap();
        run(&argv(&format!(
            "solve --spec {path} --factorization forrest_tomlin --pricing devex"
        )))
        .unwrap();
        run(&argv(&format!("solve --spec {path} --factorization markowitz"))).unwrap();
        run(&argv(&format!("solve --spec {path} --factorization bartels_golub --model nfe")))
            .unwrap();
        run(&argv(&format!("solve --spec {path} --pricing steepest_edge --model nfe"))).unwrap();
        // A generous deadline changes nothing; a bad one is usage.
        run(&argv(&format!("solve --spec {path} --timeout-ms 60000"))).unwrap();
        assert!(run(&argv(&format!("solve --spec {path} --timeout-ms soon"))).is_err());
        assert!(run(&argv(&format!("solve --spec {path} --factorization qr"))).is_err());
        assert!(run(&argv(&format!("solve --spec {path} --pricing greatest"))).is_err());
        run(&argv(&format!("simulate --spec {path} --model nfe --jitter 0.05"))).unwrap();
        run(&argv(&format!("tradeoff --spec {path} --budget-time 100"))).unwrap();
        run(&argv(&format!("speedup --spec {path} --sources 1,2"))).unwrap();
        run(&argv(&format!("sweep --spec {path} --points 5 --threads 2"))).unwrap();
        run(&argv(&format!("sweep --spec {path} --param procs --cold --model nfe"))).unwrap();
        run(&argv(&format!(
            "sweep --spec {path} --param job,procs --points 3 --steal --threads 2"
        )))
        .unwrap();
        run(&argv(&format!(
            "sweep --spec {path} --points 4 --factorization forrest_tomlin --pricing devex"
        )))
        .unwrap();
        run(&argv(&format!(
            "sweep --spec {path} --param release,links --release-points 3 --link-points 3"
        )))
        .unwrap();
        // First-order sweep backends, both spellings of the block one.
        run(&argv(&format!("sweep --spec {path} --points 4 --backend pdhg-block"))).unwrap();
        run(&argv(&format!("sweep --spec {path} --points 4 --backend pdhg_block"))).unwrap();
        run(&argv(&format!("sweep --spec {path} --points 4 --backend hybrid"))).unwrap();
        assert!(run(&argv(&format!("sweep --spec {path} --points 4 --backend cplex"))).is_err());
        // Knee refinement bisects one continuous axis.
        run(&argv(&format!(
            "sweep --spec {path} --param links --link-points 4 --refine 0.25"
        )))
        .unwrap();
        assert!(run(&argv(&format!("sweep --spec {path} --param procs --refine 0.25"))).is_err());
        assert!(run(&argv(&format!(
            "sweep --spec {path} --param job,links --points 3 --link-points 3 --refine 0.25"
        )))
        .is_err());
        assert!(run(&argv(&format!("sweep --spec {path} --points 4 --refine 0"))).is_err());
        // Bad axis ranges are usage errors, not panics.
        assert!(run(&argv(&format!("sweep --spec {path} --param links --link-from 0"))).is_err());
        assert!(run(&argv(&format!(
            "sweep --spec {path} --param release --release-from -1"
        )))
        .is_err());
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn simulate_cluster_engine_flags() {
        let path = "/tmp/dlt_cli_sim_spec.json";
        std::fs::write(
            path,
            r#"{"sources":[{"g":0.2},{"g":0.4,"release":1}],
                "processors":[{"a":2},{"a":3}],"job":10}"#,
        )
        .unwrap();
        // Gated replay of the solved LP, both models, plain and JSON.
        run(&argv(&format!("simulate --spec {path}"))).unwrap();
        run(&argv(&format!("simulate --spec {path} --model nfe --json"))).unwrap();
        // Injection grammar: outage, preemption, link window, random.
        run(&argv(&format!("simulate --spec {path} --fail p1@0.5+1.0 --trace"))).unwrap();
        run(&argv(&format!("simulate --spec {path} --preempt p2@t=1+0.5!redo --json"))).unwrap();
        run(&argv(&format!(
            "simulate --spec {path} --model nfe --link-profile s1@0+1*0.5 --rand-faults 1 --seed 3"
        )))
        .unwrap();
        // Greedy (ASAP) replay with jitter, and the legacy engine.
        run(&argv(&format!("simulate --spec {path} --asap --jitter 0.05 --seed 7"))).unwrap();
        run(&argv(&format!("simulate --spec {path} --engine legacy --jitter 0.05"))).unwrap();
        // Synthetic scale topology skips the solve entirely.
        run(&argv(&format!("simulate --spec {path} --scale 50 --json"))).unwrap();
        // Bad grammar is a usage error, never a panic.
        assert!(run(&argv(&format!("simulate --spec {path} --engine quantum"))).is_err());
        assert!(run(&argv(&format!("simulate --spec {path} --fail junk"))).is_err());
        assert!(run(&argv(&format!("simulate --spec {path} --preempt p1@1.0"))).is_err());
        assert!(run(&argv(&format!("simulate --spec {path} --link-profile s1@0+1"))).is_err());
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn batch_solves_mixed_family_request_file() {
        let path = "/tmp/dlt_cli_batch_requests.json";
        let spec = r#"{"sources":[{"g":0.2},{"g":0.4,"release":1}],
                       "processors":[{"a":2},{"a":3}],"job":10}"#;
        let body = format!(
            r#"[
              {{"id": "fe-1",  "family": "frontend",    "spec": {spec}}},
              {{"id": "nfe-1", "family": "no_frontend", "spec": {spec}}},
              {{"id": "con-1", "family": "concurrent",  "spec": {spec},
                "options": {{"mode": "proportional"}}}},
              {{"id": "mj-1",  "family": "multi_job",   "spec": {spec},
                "options": {{"proc_ready": [0.5, 1.0]}}}},
              {{"id": "pdhg-1","family": "frontend",    "spec": {spec},
                "options": {{"backend": "pdhg"}}}},
              {{"id": "ft-1",  "family": "frontend",    "spec": {spec},
                "options": {{"factorization": "forrest_tomlin", "pricing": "devex"}}}},
              {{"id": "bg-1",  "family": "frontend",    "spec": {spec},
                "options": {{"factorization": "bartels_golub"}}}},
              {{"id": "mk-1",  "family": "frontend",    "spec": {spec},
                "options": {{"factorization": "markowitz"}}}},
              {{"family": "not_a_family", "spec": {spec}}}
            ]"#
        );
        std::fs::write(path, body).unwrap();
        run(&argv(&format!("batch --requests {path} --threads 2"))).unwrap();
        run(&argv(&format!("batch --requests {path} --pretty --backend dense_tableau"))).unwrap();
        run(&argv(&format!("batch --requests {path} --backend hybrid"))).unwrap();
        run(&argv(&format!("batch --requests {path} --backend pdhg-block --threads 2"))).unwrap();
        run(&argv(&format!(
            "batch --requests {path} --factorization forrest_tomlin --pricing steepest_edge"
        )))
        .unwrap();
        // A missing file is an io error, a bad backend a usage error.
        assert!(run(&argv("batch --requests /tmp/does_not_exist_dlt.json")).is_err());
        assert!(run(&argv(&format!("batch --requests {path} --backend cplex"))).is_err());
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn serve_boots_and_drains_on_max_seconds() {
        // Port 0 binds an ephemeral port, so the test never collides.
        run(&argv("serve --port 0 --workers 1 --shards 2 --max-seconds 1")).unwrap();
        run(&argv(
            "serve --port 0 --workers 1 --degraded --default-timeout-ms 500 --max-seconds 1",
        ))
        .unwrap();
        assert!(run(&argv("serve --port 0 --backend cplex")).is_err());
    }
}
