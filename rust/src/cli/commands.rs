//! CLI subcommand implementations. Every solve goes through the
//! [`crate::api`] facade; only the PJRT-artifact PDHG path (which
//! needs a live [`crate::runtime::Runtime`]) is hand-wired.

use crate::api::{ApiError, Backend, Family, SolveRequest, Solver};
use crate::cli::args::Args;
use crate::cluster::{run_cluster, ClusterConfig, Compute};
use crate::config::json::Json;
use crate::config::spec::load_spec;
use crate::cost::{advise, Advice, Budgets, TradeoffTable};
use crate::dlt::schedule::{Schedule, TimingModel};
use crate::dlt::{frontend, no_frontend, validate};
use crate::error::{Error, Result};
use crate::lp::{Factorization, Pricing, SimplexOptions};
use crate::model::SystemSpec;
use crate::sim::{simulate as sim_run, SimOptions};

fn load(a: &Args) -> Result<SystemSpec> {
    let path = a
        .get("spec")
        .ok_or_else(|| Error::Usage("--spec FILE is required".into()))?;
    load_spec(path)
}

fn model_of(a: &Args) -> Result<TimingModel> {
    match a.get_or("model", "fe").as_str() {
        "fe" => Ok(TimingModel::FrontEnd),
        "nfe" => Ok(TimingModel::NoFrontEnd),
        other => Err(Error::Usage(format!("--model must be fe|nfe, got `{other}`"))),
    }
}

/// Simplex strategy flags shared by `solve`, `sweep` and `batch`:
/// `--factorization product_form_eta|forrest_tomlin|markowitz|bartels_golub`
/// and `--pricing dantzig|devex|steepest_edge|partial`.
fn simplex_of(a: &Args) -> Result<SimplexOptions> {
    let mut s = SimplexOptions::default();
    if let Some(f) = a.get("factorization") {
        s.factorization = Factorization::parse(f).ok_or_else(|| {
            Error::Usage(format!(
                "--factorization must be \
                 product_form_eta|forrest_tomlin|markowitz|bartels_golub, got `{f}`"
            ))
        })?;
    }
    if let Some(p) = a.get("pricing") {
        s.pricing = Pricing::parse(p).ok_or_else(|| {
            Error::Usage(format!(
                "--pricing must be dantzig|devex|steepest_edge|partial, got `{p}`"
            ))
        })?;
    }
    Ok(s)
}

/// Session backend flag shared by `sweep`, `batch` and `serve`:
/// `--backend revised_simplex|dense_tableau|pdhg|pdhg_block|hybrid`
/// (the kebab-case spelling `pdhg-block` is accepted as an alias).
fn backend_of(a: &Args) -> Result<Backend> {
    match a.get("backend") {
        None => Ok(Backend::default()),
        Some("pdhg-block") => Ok(Backend::PdhgBlock),
        Some(s) => Backend::parse(s).ok_or_else(|| {
            Error::Usage(format!(
                "--backend must be \
                 revised_simplex|dense_tableau|pdhg|pdhg_block|hybrid, got `{s}`"
            ))
        }),
    }
}

fn solve_spec(
    spec: &SystemSpec,
    model: TimingModel,
    solver: &str,
    simplex: SimplexOptions,
    timeout_ms: Option<u64>,
) -> Result<Schedule> {
    let backend = match solver {
        "simplex" => Backend::RevisedSimplex,
        "pdhg" => Backend::Pdhg,
        "pdhg-artifact" => {
            // The AOT-artifact path needs a live PJRT runtime, which
            // the session facade deliberately does not own; solve the
            // raw LP and rebuild the schedule from x.
            let lp = match model {
                TimingModel::FrontEnd => frontend::build_lp(spec, &Default::default()),
                TimingModel::NoFrontEnd => no_frontend::build_lp(spec, &Default::default()),
            };
            let mut rt = crate::runtime::Runtime::open_default()?;
            let x = crate::pdhg::solve_artifact(&mut rt, &lp, &Default::default())?.x;
            return schedule_from_lp_x(spec, model, &x);
        }
        other => {
            return Err(Error::Usage(format!(
                "--solver must be simplex|pdhg|pdhg-artifact, got `{other}`"
            )))
        }
    };
    let mut session = Solver::new().backend(backend).simplex(simplex).build();
    let mut req = SolveRequest::new(Family::from(model), spec.clone());
    req.options.timeout_ms = timeout_ms;
    let resp = session.solve(&req).map_err(|e| e.into_error())?;
    Ok(resp.schedule())
}

/// Rebuild a full `Schedule` from a raw LP solution vector.
pub fn schedule_from_lp_x(
    spec: &SystemSpec,
    model: TimingModel,
    x: &[f64],
) -> Result<Schedule> {
    let n = spec.n();
    let m = spec.m();
    let beta: Vec<f64> = x[..n * m]
        .iter()
        .map(|&b| crate::util::float::snap_nonneg(b, 1e-7))
        .collect();
    match model {
        TimingModel::FrontEnd => {
            let (ts, tf) = frontend::reconstruct_comm_windows(spec, &beta);
            let a = spec.a();
            let mut compute_start = vec![0.0; m];
            let mut compute_end = vec![0.0; m];
            for j in 0..m {
                let first = (0..n).find(|&i| beta[i * m + j] > 1e-12);
                let start = first.map(|i| ts[i * m + j]).unwrap_or(0.0);
                let total: f64 = (0..n).map(|i| beta[i * m + j]).sum::<f64>() * a[j];
                compute_start[j] = start;
                compute_end[j] = start + total;
            }
            let makespan = x[n * m];
            Ok(Schedule {
                n,
                m,
                model,
                beta,
                comm_start: ts,
                comm_end: tf,
                compute_start,
                compute_end,
                makespan,
                lp_iterations: 0,
            })
        }
        TimingModel::NoFrontEnd => {
            let v = no_frontend::NfeVars::new(n, m);
            let mut comm_start = vec![0.0; n * m];
            let mut comm_end = vec![0.0; n * m];
            for i in 0..n {
                for j in 0..m {
                    comm_start[i * m + j] = x[v.ts(i, j)];
                    comm_end[i * m + j] = x[v.tf(i, j)];
                }
            }
            let a = spec.a();
            let mut compute_start = vec![0.0; m];
            let mut compute_end = vec![0.0; m];
            for j in 0..m {
                let last = comm_end[(n - 1) * m + j];
                let total: f64 = (0..n).map(|i| beta[i * m + j]).sum();
                compute_start[j] = last;
                compute_end[j] = last + total * a[j];
            }
            Ok(Schedule {
                n,
                m,
                model,
                beta,
                comm_start,
                comm_end,
                compute_start,
                compute_end,
                makespan: x[v.makespan()],
                lp_iterations: 0,
            })
        }
    }
}

/// `dlt solve`
pub fn solve(a: &Args) -> Result<()> {
    let spec = load(a)?;
    let model = model_of(a)?;
    let solver = a.get_or("solver", "simplex");
    let timeout = a.get_usize("timeout-ms")?.map(|ms| ms as u64);
    let sched = solve_spec(&spec, model, &solver, simplex_of(a)?, timeout)?;
    println!("model: {model:?}   solver: {solver}");
    println!("T_f = {:.6}", sched.makespan);
    print!("{}", sched.render_beta_table());
    let report = validate(&spec, &sched);
    if !report.is_valid() {
        println!("VALIDATION FAILED:");
        for v in &report.violations {
            println!("  - {v}");
        }
    } else {
        println!("schedule validated OK ({} warnings)", report.warnings.len());
    }
    if spec.cost_rates().iter().any(|&c| c > 0.0) {
        println!("monetary cost = {:.2}", crate::cost::schedule_cost(&spec, &sched));
    }
    Ok(())
}

/// `dlt simulate` — replay the solved schedule through a simulator
/// engine.
///
/// `--engine cluster` (default) runs the component-based cluster
/// engine with the full injection grammar: `--fail p3@t=1.5[+DUR]`,
/// `--preempt "p2@4+1.5[!redo]"`, `--link-profile s1@10+5*0.25`
/// (each comma-separable), `--rand-faults K`, `--asap` (ignore the
/// LP's timeline and run greedy), `--scale M` (synthetic M-processor
/// topology instead of solving the spec's LP) and `--json`.
/// `--engine legacy` runs the original fixed-function replayer.
pub fn simulate(a: &Args) -> Result<()> {
    match a.get_or("engine", "cluster").as_str() {
        "cluster" => simulate_cluster(a),
        "legacy" => simulate_legacy(a),
        other => Err(Error::Usage(format!("--engine must be cluster|legacy, got `{other}`"))),
    }
}

fn simulate_cluster(a: &Args) -> Result<()> {
    use crate::sim::cluster::inject::parse_list;
    use crate::sim::cluster::{FaultSpec, InjectionPlan, LinkWindow};
    use crate::sim::replay::{replay, synthetic_scale, Gate, ReplayOptions};

    let model = model_of(a)?;
    let jitter = a.get_f64("jitter")?.unwrap_or(0.0);
    let mut plan = InjectionPlan::default();
    if let Some(s) = a.get("fail") {
        plan.faults.extend(parse_list(s, FaultSpec::parse_fail)?);
    }
    if let Some(s) = a.get("preempt") {
        plan.faults.extend(parse_list(s, FaultSpec::parse_preempt)?);
    }
    if let Some(s) = a.get("link-profile") {
        plan.link_windows = parse_list(s, LinkWindow::parse)?;
    }
    plan.random_faults = a.get_usize("rand-faults")?.unwrap_or(0);
    let opts = ReplayOptions {
        gate: if a.has("asap") { Gate::Asap } else { Gate::Schedule },
        link_jitter: jitter,
        compute_jitter: jitter,
        seed: a.get_usize("seed")?.unwrap_or(0) as u64,
        plan,
        trace: a.has("trace"),
    };

    let (spec, sched) = match a.get_usize("scale")? {
        // Synthetic scale topology: the spec only contributes sources
        // and the job size; the schedule is stamped analytically.
        Some(m) => synthetic_scale(&load(a)?, m, model)?,
        None => {
            let spec = load(a)?;
            let sched =
                solve_spec(&spec, model, &a.get_or("solver", "simplex"), simplex_of(a)?, None)?;
            (spec, sched)
        }
    };

    let mut rep = replay(&spec, &sched, &opts)?;
    let trace = rep.trace.take();
    if a.has("json") {
        println!("{}", crate::api::sim_to_json(&rep).to_string_pretty());
        return Ok(());
    }
    println!("LP predicted T_f   = {:.6}", rep.predicted_makespan);
    println!("simulated makespan = {:.6}", rep.simulated_makespan);
    println!("relative gap       = {:+.3e}", rep.rel_gap);
    println!(
        "events = {}   queue high-water = {}   faults = {}   preemptions = {}",
        rep.events, rep.max_queue_depth, rep.faults_injected, rep.preemptions
    );
    if !rep.violated_constraints.is_empty() {
        println!("violated LP promises:");
        for v in &rep.violated_constraints {
            println!("  - {v}");
        }
    }
    if let Some(tr) = trace {
        print!("{}", tr.render());
    }
    Ok(())
}

fn simulate_legacy(a: &Args) -> Result<()> {
    let spec = load(a)?;
    let model = model_of(a)?;
    let sched = solve_spec(&spec, model, &a.get_or("solver", "simplex"), simplex_of(a)?, None)?;
    let opts = SimOptions {
        model,
        link_jitter: a.get_f64("jitter")?.unwrap_or(0.0),
        compute_jitter: a.get_f64("jitter")?.unwrap_or(0.0),
        seed: a.get_usize("seed")?.unwrap_or(0) as u64,
        trace: a.has("trace"),
    };
    let res = sim_run(&spec, &sched.beta, &opts);
    println!("LP predicted T_f  = {:.6}", sched.makespan);
    println!("simulated makespan = {:.6}", res.makespan);
    println!("events processed   = {}", res.events);
    if let Some(tr) = res.trace {
        print!("{}", tr.render());
    }
    Ok(())
}

/// `dlt cluster`
pub fn cluster(a: &Args) -> Result<()> {
    let spec = load(a)?;
    let model = model_of(a)?;
    let sched = solve_spec(&spec, model, "simplex", SimplexOptions::default(), None)?;
    let compute = if a.has("real-compute") {
        let dir = a.get_or("artifacts", "artifacts");
        let a_vec = spec.a();
        let scale = a.get_f64("time-scale")?.unwrap_or(0.002);
        // Calibrate: seconds per work unit -> units per load so that
        // one load unit on P_j costs A_j * scale wall seconds.
        let mut probe = crate::runtime::WorkloadExecutable::open(&dir, 42)?;
        let sec_per_unit = probe.calibrate(8)?;
        println!("calibration: {:.3} ms per work unit", sec_per_unit * 1e3);
        let dir2 = dir.clone();
        Compute::Custom(std::sync::Arc::new(move |j: usize| {
            let mut w = crate::runtime::WorkloadExecutable::open(&dir2, 42)
                .expect("open workload in processor thread");
            let units_per_load = (a_vec[j] * scale / sec_per_unit).max(1e-9);
            let mut carry = 0.0f64;
            Box::new(move |load: f64| {
                let want = load * units_per_load + carry;
                let n = want.floor() as usize;
                carry = want - n as f64;
                w.run_units(n).expect("workload execution");
            })
        }))
    } else {
        Compute::Modeled
    };
    let cfg = ClusterConfig {
        time_scale: a.get_f64("time-scale")?.unwrap_or(0.002),
        compute,
        fe_splits: a.get_usize("fe-splits")?.unwrap_or(16),
    };
    let rep = run_cluster(&spec, &sched, &cfg)?;
    println!("predicted T_f       = {:.4}", rep.predicted_makespan);
    println!("realized  T_f       = {:.4}", rep.realized_makespan);
    println!("relative error      = {:+.2}%", rep.relative_error * 100.0);
    println!("wall clock          = {:?}", rep.wall);
    for (j, (&done, &load)) in rep.proc_done.iter().zip(rep.proc_load.iter()).enumerate() {
        println!("  P{}: load {:8.3}  done at {:8.3}", j + 1, load, done);
    }
    Ok(())
}

/// `dlt tradeoff`
pub fn tradeoff(a: &Args) -> Result<()> {
    let spec = load(a)?;
    let sweep = TradeoffTable::sweep(&spec)?;
    println!("{:>4} {:>12} {:>12} {:>12}", "m", "T_f", "cost", "gradient%");
    for (k, p) in sweep.points.iter().enumerate() {
        let g = if k == 0 {
            "".to_string()
        } else {
            format!("{:+.2}", sweep.gradients[k - 1] * 100.0)
        };
        println!("{:>4} {:>12.4} {:>12.2} {:>12}", p.m, p.tf, p.cost, g);
    }
    let budgets = Budgets {
        cost: a.get_f64("budget-cost")?,
        time: a.get_f64("budget-time")?,
        gradient_threshold: a.get_f64("gradient")?.unwrap_or(0.06),
    };
    match advise(&sweep, &budgets) {
        Advice::Use { m, tf, cost } => {
            println!("advice: use {m} processors (T_f {tf:.3}, cost {cost:.2})")
        }
        Advice::Range { lo, hi, recommended } => println!(
            "advice: any m in [{lo}, {hi}] meets both budgets; cheapest m = {recommended}"
        ),
        Advice::Infeasible { min_cost_meeting_time, min_time_within_cost } => {
            println!("advice: no processor count satisfies both budgets");
            if let Some(c) = min_cost_meeting_time {
                println!("  meeting the deadline needs a cost budget >= {c:.2}");
            }
            if let Some(t) = min_time_within_cost {
                println!("  staying in budget needs a time budget >= {t:.3}");
            }
        }
    }
    Ok(())
}

/// Evenly spaced grid values (inclusive endpoints).
fn linspace(from: f64, to: f64, points: usize) -> Vec<f64> {
    let points = points.max(1);
    let step = if points > 1 { (to - from) / (points - 1) as f64 } else { 0.0 };
    (0..points).map(|k| from + step * k as f64).collect()
}

/// `dlt sweep` — fan a (possibly multi-dimensional) scenario grid
/// across worker threads with warm-started per-thread solver state.
///
/// `--param` takes a comma-separated list of axes (`job`, `procs`,
/// `release`, `links`) crossed left-to-right into one grid; `--steal`
/// switches the scheduler from contiguous chunks to work-stealing
/// deques, which is the right choice for ragged grids (any grid with a
/// `procs` axis). `--backend pdhg-block` batches the grid into
/// first-order panels instead of per-scenario simplex solves, and
/// `--refine TOL` bisects a single continuous axis around the
/// diminishing-returns knee (see
/// [`crate::experiments::sweep::refine`]).
pub fn sweep_cmd(a: &Args) -> Result<()> {
    use crate::experiments::sweep::{
        cross_grid, refine, run_scenarios, Axis, ContinuousAxis, SweepOptions,
    };

    let spec = load(a)?;
    let model = model_of(a)?;
    let threads = a.get_usize("threads")?.unwrap_or(0);
    let opts = SweepOptions {
        threads,
        warm_start: !a.has("cold"),
        steal: a.has("steal"),
        simplex: simplex_of(a)?,
        backend: backend_of(a)?,
    };

    let param = a.get_or("param", "job");
    let mut axes: Vec<Axis> = Vec::new();
    for name in param.split(',').map(str::trim) {
        match name {
            "job" => {
                let from = a.get_f64("from")?.unwrap_or(spec.job);
                let to = a.get_f64("to")?.unwrap_or(spec.job * 5.0);
                let points = a.get_usize("points")?.unwrap_or(50);
                axes.push(Axis::Jobs(linspace(from, to, points)));
            }
            "procs" => axes.push(Axis::Procs((1..=spec.m()).collect())),
            "release" => {
                let from = a.get_f64("release-from")?.unwrap_or(0.0);
                let to = a.get_f64("release-to")?.unwrap_or(2.0);
                if !(from >= 0.0 && to >= 0.0 && from.is_finite() && to.is_finite()) {
                    return Err(Error::Usage(format!(
                        "--release-from/--release-to must be finite and >= 0, got {from}..{to}"
                    )));
                }
                let points = a.get_usize("release-points")?.unwrap_or(9);
                axes.push(Axis::ReleaseScale(linspace(from, to, points)));
            }
            "links" => {
                let from = a.get_f64("link-from")?.unwrap_or(0.5);
                let to = a.get_f64("link-to")?.unwrap_or(2.0);
                if !(from > 0.0 && to > 0.0 && from.is_finite() && to.is_finite()) {
                    return Err(Error::Usage(format!(
                        "--link-from/--link-to must be finite and > 0, got {from}..{to}"
                    )));
                }
                let points = a.get_usize("link-points")?.unwrap_or(9);
                axes.push(Axis::LinkScale(linspace(from, to, points)));
            }
            other => {
                return Err(Error::Usage(format!(
                    "--param must be a comma list of job|procs|release|links, got `{other}`"
                )))
            }
        }
    }
    if let Some(tol) = a.get_f64("refine")? {
        let threshold = a.get_f64("knee-threshold")?.unwrap_or(0.06);
        let [axis] = axes.as_slice() else {
            return Err(Error::Usage(format!(
                "--refine needs exactly one sweep axis, got {}",
                axes.len()
            )));
        };
        let (caxis, values) = match axis {
            Axis::Jobs(v) => (ContinuousAxis::Jobs, v.as_slice()),
            Axis::ReleaseScale(v) => (ContinuousAxis::ReleaseScale, v.as_slice()),
            Axis::LinkScale(v) => (ContinuousAxis::LinkScale, v.as_slice()),
            Axis::Procs(_) => {
                return Err(Error::Usage(
                    "--refine needs a continuous axis (job|release|links); \
                     the procs axis is discrete — use `dlt advise`"
                        .into(),
                ))
            }
        };
        let t0 = std::time::Instant::now();
        let r = refine(&spec, model, caxis, values, threshold, tol)?;
        let wall = t0.elapsed();
        println!("{:>24} {:>14} {:>10}", "scenario", "T_f", "lp_iters");
        for p in &r.points {
            println!("{:>24} {:>14.6} {:>10}", p.label, p.makespan, p.lp_iterations);
        }
        match r.knee {
            Some((lo, hi)) => println!(
                "knee bracket [{lo:.6}, {hi:.6}] (width {:.6}) after {} solves in {wall:?}",
                hi - lo,
                r.solves,
            ),
            None => println!(
                "no knee: every coarse step still improves >= {:.1}% per axis unit \
                 ({} solves in {wall:?})",
                threshold * 100.0,
                r.solves,
            ),
        }
        return Ok(());
    }

    let scenarios = cross_grid(&spec, model, &axes);

    let t0 = std::time::Instant::now();
    let pts = run_scenarios(&scenarios, &opts)?;
    let wall = t0.elapsed();

    println!("{:>24} {:>14} {:>10}", "scenario", "T_f", "lp_iters");
    for p in &pts {
        println!("{:>24} {:>14.6} {:>10}", p.label, p.makespan, p.lp_iterations);
    }
    let total_iters: usize = pts.iter().map(|p| p.lp_iterations).sum();
    println!(
        "{} scenarios ({} axes) in {wall:?} ({} LP iterations total, warm_start={}, \
         scheduler={}, threads={})",
        pts.len(),
        axes.len(),
        total_iters,
        opts.warm_start,
        if opts.steal { "work-stealing" } else { "chunked" },
        if threads == 0 { "auto".to_string() } else { threads.to_string() },
    );
    Ok(())
}

/// `dlt speedup`
pub fn speedup_cmd(a: &Args) -> Result<()> {
    let spec = load(a)?;
    let sources = a.get_usize_list("sources")?.unwrap_or_else(|| vec![1, 2]);
    let max_src = *sources.iter().max().unwrap_or(&1);
    if max_src > spec.n() {
        return Err(Error::Usage(format!(
            "--sources asks for {max_src} sources but the spec has {}",
            spec.n()
        )));
    }
    let pts = crate::speedup::sweep(&spec, &sources, spec.m())?;
    print!("{:>4}", "m");
    for p in &sources {
        print!(" {:>10}", format!("S({p}src)"));
    }
    println!();
    for m in 1..=spec.m() {
        print!("{m:>4}");
        for &p in &sources {
            // A grid point can be missing if a scenario solve was
            // dropped (e.g. an infeasible (p, m) cell) — report it
            // instead of panicking mid-table.
            let pt = pts
                .iter()
                .find(|x| x.sources == p && x.processors == m)
                .ok_or_else(|| {
                    Error::Numerical(format!(
                        "speedup sweep lost the ({p} sources, {m} processors) grid point"
                    ))
                })?;
            print!(" {:>10.4}", pt.speedup);
        }
        println!();
    }
    Ok(())
}

/// `dlt batch` — the serving front door: read a JSON array of
/// [`SolveRequest`]s from `--requests FILE` (or stdin when the flag is
/// absent or `-`), solve them through one work-stealing
/// [`crate::api::Session`] batch, and emit a JSON array of
/// response-or-error objects in the same order. A malformed element
/// becomes an in-band `{"error": ...}` entry at its slot; only a
/// top-level malformation (unreadable file, non-array document) fails
/// the command.
pub fn batch(a: &Args) -> Result<()> {
    let text = match a.get("requests") {
        None | Some("-") => {
            use std::io::Read;
            let mut buf = String::new();
            std::io::stdin()
                .read_to_string(&mut buf)
                .map_err(|e| Error::io("<stdin>", e))?;
            buf
        }
        Some(path) => std::fs::read_to_string(path).map_err(|e| Error::io(path, e))?,
    };
    let doc = Json::parse(&text)?;
    let items = doc.as_array()?;

    let backend = backend_of(a)?;
    let threads = a.get_usize("threads")?.unwrap_or(0);

    let parsed: Vec<std::result::Result<SolveRequest, ApiError>> = items
        .iter()
        .map(|it| SolveRequest::from_json(it).map_err(ApiError::from))
        .collect();
    let good: Vec<SolveRequest> = parsed.iter().filter_map(|r| r.as_ref().ok().cloned()).collect();

    let session =
        Solver::new().backend(backend).threads(threads).simplex(simplex_of(a)?).build();
    let t0 = std::time::Instant::now();
    let results = session.solve_batch(&good);
    let wall = t0.elapsed();

    let mut ok = 0usize;
    let mut warm = 0usize;
    let mut results = results.into_iter();
    let out: Vec<Json> = parsed
        .into_iter()
        .map(|p| match p {
            Err(e) => e.to_json(),
            Ok(_) => match results.next() {
                Some(Ok(resp)) => {
                    ok += 1;
                    if resp.diagnostics.warm_start {
                        warm += 1;
                    }
                    resp.to_json()
                }
                Some(Err(e)) => e.to_json(),
                None => unreachable!("one batch result per parsed request"),
            },
        })
        .collect();
    let doc = Json::Array(out);
    if a.has("pretty") {
        print!("{}", doc.to_string_pretty());
    } else {
        println!("{}", doc.to_string_compact());
    }
    let solved = good.len();
    let secs = wall.as_secs_f64().max(1e-9);
    eprintln!(
        "{} requests ({} ok, {} failed, {} warm-started) in {wall:?} ({:.0} req/s)",
        items.len(),
        ok,
        items.len() - ok,
        warm,
        solved as f64 / secs,
    );
    Ok(())
}

/// `dlt experiments`
pub fn experiments(a: &Args) -> Result<()> {
    let names: Vec<&str> = match a.get("exp") {
        Some(one) => vec![one],
        None => crate::experiments::ALL.to_vec(),
    };
    for name in names {
        let t = crate::experiments::run(name)?;
        println!("{}", t.render_text());
        if let Some(dir) = a.get("csv-dir") {
            let path = t.write_csv(dir)?;
            println!("  wrote {path}");
        }
    }
    Ok(())
}

/// `dlt artifacts`
pub fn artifacts(a: &Args) -> Result<()> {
    let dir = a.get_or("artifacts", "artifacts");
    let rt = crate::runtime::Runtime::open(&dir)?;
    println!("platform: {}", rt.platform());
    println!("pdhg variants:");
    for v in &rt.manifest().pdhg {
        println!("  {:30} nv={:5} nc={:5} steps={}", v.name, v.nv, v.nc, v.steps);
    }
    println!("workload variants:");
    for w in &rt.manifest().workload {
        println!("  {:30} {}x{}", w.name, w.rows, w.cols);
    }
    Ok(())
}

/// `dlt serve`: boot the zero-dependency TCP serving tier and block
/// until shutdown. `--max-seconds N` runs for a bounded window (used
/// by CI smoke tests), drains gracefully and prints final counters;
/// without it the server runs until the process is killed.
pub fn serve(a: &Args) -> Result<()> {
    use crate::serve::{ServeOptions, Server};

    let backend = backend_of(a)?;

    let mut opts = ServeOptions::default();
    let host = a.get_or("host", "127.0.0.1");
    let port = a.get_usize("port")?.unwrap_or(4517);
    opts.addr = format!("{host}:{port}");
    if let Some(w) = a.get_usize("workers")? {
        opts.workers = w;
    }
    if let Some(s) = a.get_usize("shards")? {
        opts.shards = s;
    }
    if let Some(q) = a.get_usize("queue-depth")? {
        opts.queue_depth = q;
    }
    if let Some(kb) = a.get_usize("warm-budget-kb")? {
        opts.warm_budget_bytes = kb.saturating_mul(1024);
    }
    if let Some(ms) = a.get_usize("retry-after-ms")? {
        opts.retry_after_ms = ms as u64;
    }
    opts.degraded = a.has("degraded");
    if let Some(ms) = a.get_usize("default-timeout-ms")? {
        opts.default_timeout_ms = (ms > 0).then_some(ms as u64);
    }
    opts.solver = Solver::new().backend(backend).simplex(simplex_of(a)?);

    let server = Server::start(opts)?;
    eprintln!(
        "dlt serve listening on {} ({} workers, {} shards)",
        server.local_addr(),
        server.workers(),
        server.shards(),
    );

    match a.get_usize("max-seconds")? {
        Some(secs) if secs > 0 => {
            std::thread::sleep(std::time::Duration::from_secs(secs as u64));
            let stats = server.shutdown();
            eprintln!(
                "drained: {} conns, {} requests, {} responses, {} shed, {} expired, \
                 {} degraded, {} malformed, {} evictions, {}/{} shard hits/misses, \
                 {} resident",
                stats.connections,
                stats.requests,
                stats.responses,
                stats.shed,
                stats.expired,
                stats.degraded,
                stats.malformed,
                stats.evictions,
                stats.shard_hits,
                stats.shard_misses,
                stats.resident_sessions,
            );
            Ok(())
        }
        _ => {
            server.join();
            Ok(())
        }
    }
}
