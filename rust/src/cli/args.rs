//! Tiny flag parser: `--key value`, `--flag`, one positional
//! subcommand.

use crate::error::{Error, Result};
use std::collections::HashMap;

/// Parsed arguments.
#[derive(Debug, Clone, Default)]
pub struct Args {
    /// First positional token.
    pub subcommand: String,
    flags: HashMap<String, String>,
}

/// Flags that take no value.
const BOOL_FLAGS: &[&str] = &[
    "trace",
    "real-compute",
    "csv",
    "quiet",
    "cold",
    "steal",
    "pretty",
    "json",
    "asap",
    "degraded",
];

impl Args {
    /// Parse argv (without the binary name).
    pub fn parse(argv: &[String]) -> Result<Args> {
        let mut out = Args::default();
        let mut it = argv.iter().peekable();
        if let Some(first) = it.peek() {
            if !first.starts_with("--") {
                out.subcommand = it.next().unwrap().clone();
            }
        }
        while let Some(tok) = it.next() {
            let Some(key) = tok.strip_prefix("--") else {
                return Err(Error::Usage(format!("unexpected positional `{tok}`")));
            };
            if BOOL_FLAGS.contains(&key) {
                out.flags.insert(key.to_string(), "true".to_string());
            } else {
                let val = it
                    .next()
                    .ok_or_else(|| Error::Usage(format!("flag --{key} needs a value")))?;
                out.flags.insert(key.to_string(), val.clone());
            }
        }
        Ok(out)
    }

    /// String flag.
    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    /// String flag with default.
    pub fn get_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    /// Float flag.
    pub fn get_f64(&self, key: &str) -> Result<Option<f64>> {
        self.get(key)
            .map(|v| {
                v.parse::<f64>()
                    .map_err(|_| Error::Usage(format!("--{key} expects a number, got `{v}`")))
            })
            .transpose()
    }

    /// Integer flag.
    pub fn get_usize(&self, key: &str) -> Result<Option<usize>> {
        self.get(key)
            .map(|v| {
                v.parse::<usize>()
                    .map_err(|_| Error::Usage(format!("--{key} expects an integer, got `{v}`")))
            })
            .transpose()
    }

    /// Boolean flag presence.
    pub fn has(&self, key: &str) -> bool {
        self.flags.contains_key(key)
    }

    /// Comma-separated usize list.
    pub fn get_usize_list(&self, key: &str) -> Result<Option<Vec<usize>>> {
        self.get(key)
            .map(|v| {
                v.split(',')
                    .map(|s| {
                        s.trim().parse::<usize>().map_err(|_| {
                            Error::Usage(format!("--{key}: bad integer `{s}`"))
                        })
                    })
                    .collect()
            })
            .transpose()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        let v: Vec<String> = s.split_whitespace().map(String::from).collect();
        Args::parse(&v).unwrap()
    }

    #[test]
    fn subcommand_and_flags() {
        let a = parse("solve --spec x.json --model nfe --trace");
        assert_eq!(a.subcommand, "solve");
        assert_eq!(a.get("spec"), Some("x.json"));
        assert_eq!(a.get_or("model", "fe"), "nfe");
        assert!(a.has("trace"));
        assert!(!a.has("quiet"));
    }

    #[test]
    fn typed_flags() {
        let a = parse("x --jitter 0.25 --seed 7 --sources 1,2,10");
        assert_eq!(a.get_f64("jitter").unwrap(), Some(0.25));
        assert_eq!(a.get_usize("seed").unwrap(), Some(7));
        assert_eq!(a.get_usize_list("sources").unwrap(), Some(vec![1, 2, 10]));
        assert_eq!(a.get_f64("missing").unwrap(), None);
    }

    #[test]
    fn errors() {
        let v: Vec<String> = vec!["x".into(), "--spec".into()];
        assert!(Args::parse(&v).is_err());
        let a = parse("x --jitter abc");
        assert!(a.get_f64("jitter").is_err());
        let v: Vec<String> = vec!["x".into(), "stray".into()];
        assert!(Args::parse(&v).is_err());
    }

    #[test]
    fn no_subcommand() {
        let a = parse("--csv");
        assert_eq!(a.subcommand, "");
        assert!(a.has("csv"));
    }
}
