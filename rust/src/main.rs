//! `dlt` CLI entrypoint.
fn main() {
    dlt::util::logger::init();
    let args: Vec<String> = std::env::args().collect();
    if let Err(e) = dlt::cli::run(&args) {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}
