//! Bench: Table 4 / Figs. 14–15 — homogeneous speedup analysis.
//! The N=10 no-front-end LP (541 vars) is the heaviest solve in the
//! paper's evaluation; this bench tracks it explicitly.

use dlt::benchkit::{Bencher, Reporter};
use dlt::dlt::no_frontend::NfeOptions;
use dlt::pipeline;
use dlt::experiments::{params, run};

fn main() {
    let b = Bencher::from_env();
    let mut rep = Reporter::new("fig14_15 (homogeneous speedup, NFE)");

    let spec = params::table4();
    for n in [1usize, 3, 10] {
        let sub = spec.with_n_sources(n).with_m_processors(12);
        rep.report(
            &format!("solve_nfe_n{n}_m12"),
            b.bench_val(|| pipeline::solve(&NfeOptions::default(), &sub).unwrap()),
        );
    }
    rep.finish();

    println!("{}", run("fig14").unwrap().render_text());
    println!("{}", run("fig15").unwrap().render_text());
}
