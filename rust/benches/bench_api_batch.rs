//! Batch-serving throughput through the `dlt::api` facade: mixed-family
//! request vectors through `Session::solve_batch` (work-stealing, one
//! session per worker), sequential session solves as the baseline, and
//! the JSON wire overhead. Reports requests/sec and the warm-hit rate
//! alongside the timings; `DLT_BENCH_JSON_DIR` emits
//! `BENCH_api_batch.json` for the CI perf trajectory.

use dlt::api::{Family, RequestOptions, SolveRequest, Solver, FAMILIES};
use dlt::benchkit::{Bencher, Reporter};
use dlt::dlt::concurrent::Mode;
use dlt::model::SystemSpec;

fn base_spec() -> SystemSpec {
    SystemSpec::builder()
        .source(0.2, 1.0)
        .source(0.3, 3.0)
        .processors(&[2.0, 2.5, 3.0, 3.5, 4.0, 4.5])
        .job(100.0)
        .build()
        .unwrap()
}

/// A mixed-family request vector shaped like real serving traffic:
/// job-size perturbations across all four families.
fn request_vector(count: usize) -> Vec<SolveRequest> {
    let spec = base_spec();
    (0..count)
        .map(|k| {
            let family = FAMILIES[k % FAMILIES.len()];
            let sub = spec.with_job(60.0 + 5.0 * (k % 17) as f64);
            let mut req = SolveRequest::new(family, sub);
            req.id = Some(format!("bench-{k}"));
            if family == Family::Concurrent {
                req.options = RequestOptions {
                    mode: Some(if k % 2 == 0 { Mode::Staggered } else { Mode::Proportional }),
                    ..RequestOptions::default()
                };
            }
            req
        })
        .collect()
}

fn main() {
    let fast = std::env::var("DLT_BENCH_FAST").is_ok();
    let count = if fast { 48 } else { 192 };
    let reqs = request_vector(count);

    let mut rep = Reporter::new("api_batch").slug("api_batch");
    let b = Bencher::from_env();

    // Sequential baseline: one warm session, requests in order.
    rep.report(
        &format!("sequential_session_{count}req"),
        b.bench_val(|| {
            let mut session = Solver::new().build();
            let mut ok = 0usize;
            for req in &reqs {
                if session.solve(req).is_ok() {
                    ok += 1;
                }
            }
            ok
        }),
    );

    for threads in [2usize, 4] {
        rep.report(
            &format!("solve_batch_{count}req_t{threads}"),
            b.bench_val(|| {
                Solver::new().threads(threads).build().solve_batch(&reqs)
            }),
        );
    }

    // Wire overhead: encode + parse the whole request vector.
    rep.report(
        &format!("wire_roundtrip_{count}req"),
        b.bench_val(|| {
            reqs.iter()
                .map(|r| {
                    let text = r.to_json().to_string_compact();
                    SolveRequest::parse(&text).expect("roundtrip")
                })
                .count()
        }),
    );

    // Throughput + warm-hit rate from one measured batch run.
    let t0 = std::time::Instant::now();
    let out = Solver::new().threads(4).build().solve_batch(&reqs);
    let wall = t0.elapsed().as_secs_f64().max(1e-9);
    let ok = out.iter().filter(|r| r.is_ok()).count();
    let warm = out
        .iter()
        .filter(|r| r.as_ref().map(|x| x.diagnostics.warm_start).unwrap_or(false))
        .count();
    rep.note(&format!(
        "batch throughput: {:.0} req/s ({ok}/{} ok, t4)",
        ok as f64 / wall,
        out.len()
    ));
    rep.note(&format!(
        "warm-hit rate: {:.1}% ({warm}/{} responses warm-started)",
        100.0 * warm as f64 / out.len().max(1) as f64,
        out.len()
    ));
    rep.finish();
}
