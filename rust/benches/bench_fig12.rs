//! Bench: Table 3 / Fig. 12 — finish time vs sources × processors
//! (no front-ends).

use dlt::benchkit::{Bencher, Reporter};
use dlt::dlt::no_frontend::NfeOptions;
use dlt::pipeline;
use dlt::experiments::{params, run};

fn main() {
    let b = Bencher::from_env();
    let mut rep = Reporter::new("fig12 (T_f vs N sources x M processors, NFE)");

    let spec = params::table3();
    for (n, m) in [(1usize, 5usize), (2, 10), (3, 20)] {
        let sub = spec.with_n_sources(n).with_m_processors(m);
        rep.report(
            &format!("solve_nfe_n{n}_m{m}"),
            b.bench_val(|| pipeline::solve(&NfeOptions::default(), &sub).unwrap()),
        );
    }
    let full = run("fig12").unwrap();
    rep.note("full 3x20 sweep below");
    rep.finish();
    println!("{}", full.render_text());
}
