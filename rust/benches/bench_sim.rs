//! Bench: the component-based cluster replay engine.
//!
//! Three sections:
//!
//! - **engine cells** — synthetic topologies at m = 100 / 1 000 /
//!   10 000 processors ([`dlt::sim::replay::synthetic_scale`]),
//!   jitter-free Schedule-gated replay. The stamped makespan must be
//!   reproduced *bit-for-bit* (`rel_gap == 0.0` exactly — the
//!   determinism contract, not a tolerance), and events/s is the
//!   throughput story for the 10k-scale acceptance bar.
//! - **replay overhead** — the legacy fixed-function replayer vs the
//!   component engine in greedy (`Gate::Asap`) mode on the same
//!   solved anchor: what the component indirection costs.
//! - **fault sweep** — one growing processor outage injected into a
//!   gated replay; the simulated makespan must be non-decreasing in
//!   the outage duration (injection monotonicity gate).
//!
//! With `DLT_BENCH_JSON_DIR=dir` the results land in
//! `dir/BENCH_sim.json`; `DLT_BENCH_FAST=1` trims repetitions only —
//! the m grid stays, the schema gate needs all three scales.

use dlt::config::json::Json;
use dlt::dlt::no_frontend::NfeOptions;
use dlt::dlt::schedule::TimingModel;
use dlt::model::SystemSpec;
use dlt::pipeline;
use dlt::sim::cluster::FaultSpec;
use dlt::sim::replay::{replay, synthetic_scale, Gate, ReplayOptions};
use dlt::sim::{simulate, SimOptions};
use std::time::Instant;

fn base_spec() -> SystemSpec {
    SystemSpec::builder()
        .source(0.2, 0.0)
        .source(0.3, 2.0)
        .processors(&[2.0, 3.0, 4.0])
        .job(100.0)
        .build()
        .unwrap()
}

struct EngineCell {
    m: usize,
    n: usize,
    events: u64,
    max_queue_depth: usize,
    wall_ns: f64,
    events_per_sec: f64,
    makespan: f64,
    rel_gap: f64,
}

fn engine_cell(base: &SystemSpec, m: usize, reps: usize) -> EngineCell {
    let (spec, sched) =
        synthetic_scale(base, m, TimingModel::NoFrontEnd).expect("synthetic topology");
    let opts = ReplayOptions::default();
    let mut best_ns = f64::INFINITY;
    let mut last = None;
    for _ in 0..reps {
        let t0 = Instant::now();
        let rep = replay(&spec, &sched, &opts).expect("gated replay");
        best_ns = best_ns.min(t0.elapsed().as_nanos() as f64);
        last = Some(rep);
    }
    let rep = last.expect("at least one rep");
    // Determinism contract, not a tolerance: the stamped makespan is
    // reproduced bit-for-bit by a jitter-free fault-free replay.
    assert!(
        rep.rel_gap == 0.0 && rep.violated_constraints.is_empty(),
        "m={m}: jitter-free replay drifted (gap {:+.3e}, {} violations)",
        rep.rel_gap,
        rep.violated_constraints.len()
    );
    EngineCell {
        m,
        n: spec.n(),
        events: rep.events,
        max_queue_depth: rep.max_queue_depth,
        wall_ns: best_ns,
        events_per_sec: rep.events as f64 / (best_ns * 1e-9),
        makespan: rep.simulated_makespan,
        rel_gap: rep.rel_gap,
    }
}

fn main() {
    let fast = std::env::var("DLT_BENCH_FAST").is_ok();
    let cell_reps = if fast { 1 } else { 3 };
    let overhead_reps = if fast { 5 } else { 50 };
    let base = base_spec();

    println!("== bench group: sim (cluster replay engine) ==");

    // --- engine cells ---
    let cells: Vec<EngineCell> =
        [100usize, 1000, 10_000].iter().map(|&m| engine_cell(&base, m, cell_reps)).collect();
    println!(
        "{:<10} {:>8} {:>10} {:>8} {:>12} {:>14} {:>12}",
        "cell", "events", "wall", "queue", "events/s", "makespan", "rel_gap"
    );
    for c in &cells {
        println!(
            "m={:<8} {:>8} {:>8.2}ms {:>8} {:>10.2}M/s {:>14.6} {:>12.1e}",
            c.m,
            c.events,
            c.wall_ns * 1e-6,
            c.max_queue_depth,
            c.events_per_sec / 1e6,
            c.makespan,
            c.rel_gap
        );
    }

    // --- replay overhead: legacy engine vs component engine ---
    let spec = base_spec();
    let sched = pipeline::solve(&NfeOptions::default(), &spec).expect("anchor solve");
    let legacy_opts = SimOptions { model: TimingModel::NoFrontEnd, ..SimOptions::default() };
    let t0 = Instant::now();
    for _ in 0..overhead_reps {
        simulate(&spec, &sched.beta, &legacy_opts);
    }
    let legacy_ns = t0.elapsed().as_nanos() as f64 / overhead_reps as f64;
    let asap_opts = ReplayOptions { gate: Gate::Asap, ..ReplayOptions::default() };
    let t0 = Instant::now();
    for _ in 0..overhead_reps {
        replay(&spec, &sched, &asap_opts).expect("asap replay");
    }
    let cluster_ns = t0.elapsed().as_nanos() as f64 / overhead_reps as f64;
    let ratio = cluster_ns / legacy_ns.max(1.0);
    let overhead_note = format!(
        "replay overhead (nfe 2x3 anchor): legacy {legacy_ns:.0}ns vs cluster \
         {cluster_ns:.0}ns ({ratio:.2}x)"
    );
    println!("   note: {overhead_note}");

    // --- fault sweep: outage duration vs simulated makespan ---
    let durations = [0.0f64, 0.25, 0.5, 1.0, 2.0];
    let fault_at = sched.makespan * 0.25;
    let mut makespans = Vec::new();
    for &d in &durations {
        let mut opts = ReplayOptions::default();
        if d > 0.0 {
            opts.plan.faults.push(FaultSpec {
                processor: 0,
                at: fault_at,
                duration: Some(d),
                redo: true,
                blocks_recv: true,
            });
        }
        let rep = replay(&spec, &sched, &opts).expect("fault replay");
        makespans.push(rep.simulated_makespan);
    }
    for w in makespans.windows(2) {
        assert!(
            w[1] >= w[0],
            "fault sweep regressed: longer outage finished earlier ({} < {})",
            w[1],
            w[0]
        );
    }
    let sweep_note = format!(
        "fault sweep (outage at t={fault_at:.3}): makespans {:?} non-decreasing",
        makespans.iter().map(|x| (x * 1e3).round() / 1e3).collect::<Vec<f64>>()
    );
    println!("   note: {sweep_note}");

    // --- JSON artifact ---
    let cell_json: Vec<Json> = cells
        .iter()
        .map(|c| {
            Json::Object(vec![
                ("m".into(), Json::Num(c.m as f64)),
                ("n".into(), Json::Num(c.n as f64)),
                ("events".into(), Json::Num(c.events as f64)),
                ("max_queue_depth".into(), Json::Num(c.max_queue_depth as f64)),
                ("wall_ns".into(), Json::Num(c.wall_ns)),
                ("events_per_sec".into(), Json::Num(c.events_per_sec)),
                ("makespan".into(), Json::Num(c.makespan)),
                ("rel_gap".into(), Json::Num(c.rel_gap)),
            ])
        })
        .collect();
    let notes = Json::Array(vec![Json::Str(overhead_note), Json::Str(sweep_note)]);
    let doc = Json::Object(vec![
        ("group".into(), Json::Str("sim".into())),
        (
            "instance".into(),
            Json::Str(format!(
                "synthetic nfe topologies from a 2-source anchor, {cell_reps} rep(s) per cell"
            )),
        ),
        ("engine_cells".into(), Json::Array(cell_json)),
        (
            "replay_overhead".into(),
            Json::Object(vec![
                ("legacy_ns".into(), Json::Num(legacy_ns)),
                ("cluster_ns".into(), Json::Num(cluster_ns)),
                ("ratio".into(), Json::Num(ratio)),
            ]),
        ),
        (
            "fault_sweep".into(),
            Json::Object(vec![
                ("fault_at".into(), Json::Num(fault_at)),
                (
                    "durations".into(),
                    Json::Array(durations.iter().map(|&d| Json::Num(d)).collect()),
                ),
                (
                    "makespans".into(),
                    Json::Array(makespans.iter().map(|&t| Json::Num(t)).collect()),
                ),
            ]),
        ),
        ("notes".into(), notes),
    ]);
    if let Ok(dir) = std::env::var("DLT_BENCH_JSON_DIR") {
        std::fs::create_dir_all(&dir).expect("create bench json dir");
        let path = std::path::Path::new(&dir).join("BENCH_sim.json");
        std::fs::write(&path, doc.to_string_pretty()).expect("write BENCH_sim.json");
        println!("   wrote {}", path.display());
    }
}
