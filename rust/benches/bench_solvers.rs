//! Bench: LP solver back-ends — simplex vs pure-rust PDHG vs the AOT
//! PDHG artifact (PJRT), across growing N × M scheduling instances.
//!
//! Not a paper figure; this is the §Perf harness for the solving hot
//! path (see EXPERIMENTS.md §Perf).

use dlt::benchkit::{Bencher, Reporter};
use dlt::dlt::{frontend, no_frontend};
use dlt::lp::solve;
use dlt::model::SystemSpec;
use dlt::pdhg::{solve_artifact, solve_rust, PdhgOptions};
use dlt::runtime::Runtime;

fn spec(n: usize, m: usize) -> SystemSpec {
    let mut b = SystemSpec::builder();
    for i in 0..n {
        b = b.source(0.5 + 0.01 * i as f64, i as f64 * 0.5);
    }
    let a: Vec<f64> = (0..m).map(|k| 1.1 + 0.1 * k as f64).collect();
    b.processors(&a).job(100.0).build().unwrap()
}

fn main() {
    let b = Bencher::from_env();
    let mut rep = Reporter::new("solver backends (simplex vs PDHG vs PDHG artifact)");

    for (n, m) in [(2usize, 5usize), (3, 10), (3, 20)] {
        let s = spec(n, m);
        let lp_fe = frontend::build_lp(&s, &Default::default());
        rep.report(
            &format!("simplex_fe_n{n}_m{m} ({} vars)", lp_fe.num_vars()),
            b.bench_val(|| solve(&lp_fe).unwrap()),
        );
        let lp_nfe = no_frontend::build_lp(&s, &Default::default());
        rep.report(
            &format!("simplex_nfe_n{n}_m{m} ({} vars)", lp_nfe.num_vars()),
            b.bench_val(|| solve(&lp_nfe).unwrap()),
        );
    }

    // PDHG comparisons on the Table-1-shaped FE LP.
    let s = spec(2, 5);
    let lp = frontend::build_lp(&s, &Default::default());
    let opts = PdhgOptions::default();
    rep.report(
        "pdhg_rust_fe_n2_m5",
        b.bench_val(|| solve_rust(&lp, 64, 64, &opts).unwrap()),
    );

    if Runtime::artifacts_available() {
        let mut rt = Runtime::open_default().expect("open runtime");
        // Warm the compile cache outside the timed region.
        let _ = solve_artifact(&mut rt, &lp, &opts).expect("warm");
        rep.report(
            "pdhg_artifact_fe_n2_m5",
            b.bench_val(|| solve_artifact(&mut rt, &lp, &opts).unwrap()),
        );
    } else {
        rep.note("artifacts/ not built; skipping pdhg_artifact bench");
    }
    rep.finish();
}
