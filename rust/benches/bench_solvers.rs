//! Bench: LP solver back-ends — dense tableau vs sparse revised
//! simplex (cold and warm-started), plus the pure-rust PDHG and the
//! AOT PDHG artifact (PJRT), across growing N × M scheduling
//! instances, and warm/parallel scenario sweeps.
//!
//! Not a paper figure; this is the §Perf harness for the solving hot
//! path. With `DLT_BENCH_JSON_DIR=dir` the results land in
//! `dir/BENCH_solvers.json` so the perf trajectory is tracked across
//! commits.

use dlt::benchkit::{Bencher, Reporter};
use dlt::dlt::schedule::TimingModel;
use dlt::dlt::{frontend, no_frontend};
use dlt::experiments::sweep::{job_grid, run_scenarios, SweepOptions};
use dlt::lp::{solve_with, SimplexOptions, SolverBackend};
use dlt::model::SystemSpec;
use dlt::pdhg::{solve_artifact, solve_rust, PdhgOptions};
use dlt::runtime::Runtime;

fn spec(n: usize, m: usize) -> SystemSpec {
    let mut b = SystemSpec::builder();
    for i in 0..n {
        b = b.source(0.5 + 0.01 * i as f64, i as f64 * 0.5);
    }
    let a: Vec<f64> = (0..m).map(|k| 1.1 + 0.1 * k as f64).collect();
    b.processors(&a).job(100.0).build().unwrap()
}

fn sweep_opts(threads: usize, warm_start: bool) -> SweepOptions {
    SweepOptions { threads, warm_start, steal: false, ..SweepOptions::default() }
}

fn main() {
    let b = Bencher::from_env();
    let mut rep =
        Reporter::new("solver backends (dense vs revised-sparse vs PDHG)").slug("solvers");

    let dense = SimplexOptions { backend: SolverBackend::DenseTableau, ..Default::default() };
    let revised = SimplexOptions::default(); // RevisedSparse

    for (n, m) in [(2usize, 5usize), (3, 10), (3, 20)] {
        let s = spec(n, m);
        let lp_fe = frontend::build_lp(&s, &Default::default());
        rep.report(
            &format!("dense_fe_n{n}_m{m} ({} vars)", lp_fe.num_vars()),
            b.bench_val(|| solve_with(&lp_fe, &dense).unwrap()),
        );
        rep.report(
            &format!("revised_fe_n{n}_m{m} ({} vars)", lp_fe.num_vars()),
            b.bench_val(|| solve_with(&lp_fe, &revised).unwrap()),
        );
        let lp_nfe = no_frontend::build_lp(&s, &Default::default());
        rep.report(
            &format!("dense_nfe_n{n}_m{m} ({} vars)", lp_nfe.num_vars()),
            b.bench_val(|| solve_with(&lp_nfe, &dense).unwrap()),
        );
        rep.report(
            &format!("revised_nfe_n{n}_m{m} ({} vars)", lp_nfe.num_vars()),
            b.bench_val(|| solve_with(&lp_nfe, &revised).unwrap()),
        );
    }

    // Warm-started 50-point job sweep vs 50 cold solves on the largest
    // instance, then the same sweep fanned across all cores.
    let s = spec(3, 20);
    let jobs: Vec<f64> = (0..50).map(|k| 100.0 + 4.0 * k as f64).collect();
    for (tag, model) in
        [("fe", TimingModel::FrontEnd), ("nfe", TimingModel::NoFrontEnd)]
    {
        let grid = job_grid(&s, &jobs, model);
        rep.report(
            &format!("sweep50_cold_{tag}_n3_m20"),
            b.bench_val(|| {
                run_scenarios(&grid, &sweep_opts(1, false)).unwrap()
            }),
        );
        rep.report(
            &format!("sweep50_warm_{tag}_n3_m20"),
            b.bench_val(|| {
                run_scenarios(&grid, &sweep_opts(1, true)).unwrap()
            }),
        );
        rep.report(
            &format!("sweep50_warm_par_{tag}_n3_m20"),
            b.bench_val(|| {
                run_scenarios(&grid, &sweep_opts(0, true)).unwrap()
            }),
        );
    }

    // Ragged multi-dimensional grid (procs x job): chunked vs
    // work-stealing scheduling of the same 100 scenarios.
    {
        use dlt::experiments::sweep::{cross_grid, Axis};
        let s = spec(3, 20);
        let grid = cross_grid(
            &s,
            TimingModel::FrontEnd,
            &[
                Axis::Procs((1..=20).collect()),
                Axis::Jobs((0..5).map(|k| 100.0 + 40.0 * k as f64).collect()),
            ],
        );
        rep.report(
            "ragged100_chunked_fe_n3",
            b.bench_val(|| {
                run_scenarios(&grid, &SweepOptions { threads: 0, warm_start: true, steal: false, ..SweepOptions::default() })
                    .unwrap()
            }),
        );
        rep.report(
            "ragged100_steal_fe_n3",
            b.bench_val(|| {
                run_scenarios(&grid, &SweepOptions { threads: 0, warm_start: true, steal: true, ..SweepOptions::default() })
                    .unwrap()
            }),
        );
    }

    // PDHG comparisons on the Table-1-shaped FE LP.
    let s = spec(2, 5);
    let lp = frontend::build_lp(&s, &Default::default());
    let opts = PdhgOptions::default();
    rep.report(
        "pdhg_rust_fe_n2_m5",
        b.bench_val(|| solve_rust(&lp, &opts).unwrap()),
    );

    if Runtime::artifacts_available() {
        let mut rt = Runtime::open_default().expect("open runtime");
        // Warm the compile cache outside the timed region.
        let _ = solve_artifact(&mut rt, &lp, &opts).expect("warm");
        rep.report(
            "pdhg_artifact_fe_n2_m5",
            b.bench_val(|| solve_artifact(&mut rt, &lp, &opts).unwrap()),
        );
    } else {
        rep.note("artifacts/ not built; skipping pdhg_artifact bench");
    }
    rep.finish();
}
