//! Bench: the sparse / batched / hybrid first-order solving tier.
//!
//! Four sections:
//!
//! - **matvec cells** — the CSC O(nnz) PDHG matvec against a dense
//!   row-major matvec over the *same* standardized constraint matrix,
//!   on growing FE instances. The scheduling matrices are ~95 % zeros,
//!   so the sparse kernel must win by a wide margin on the largest
//!   cell (the gate in `scripts/check_bench_schema.py` demands >= 4x).
//! - **block cells** — [`dlt::pdhg::solve_block`] panels of width
//!   1 / 4 / 16 job-scaled scenarios against the same scenarios solved
//!   one by one with [`dlt::pdhg::solve_rust`]: one shared matrix pass
//!   and one `||A||` power iteration per panel, per-column early
//!   retirement. The width-16 throughput gate is >= 2x sequential.
//! - **hybrid** — a warm-session job sweep through `Backend::Hybrid`
//!   (loosened PDHG stage, crossover basis guess, warm simplex
//!   cleanup) vs the same sweep on cold revised simplex; the cleanup
//!   pivot total must not exceed the cold pivot total.
//! - **refine** — [`dlt::experiments::sweep::refine`] knee bisection
//!   on a link-scale axis vs the uniform fine grid that would reach
//!   the same bracket resolution.
//!
//! With `DLT_BENCH_JSON_DIR=dir` the results land in
//! `dir/BENCH_pdhg_hybrid.json`; `DLT_BENCH_FAST=1` trims repetitions
//! and block budgets; `DLT_BENCH_ASSERT=1` turns the gates into
//! in-process panics (CI leaves it unset so the JSON artifact survives
//! a regression and the python step stays the single gate).

use dlt::api::{Backend, Family, SolveRequest, Solver};
use dlt::config::json::Json;
use dlt::dlt::frontend;
use dlt::dlt::schedule::TimingModel;
use dlt::experiments::sweep::{refine, ContinuousAxis};
use dlt::model::SystemSpec;
use dlt::pdhg::{solve_block, solve_rust, PdhgOptions, SparseLp};
use std::time::Instant;

fn spec(n: usize, m: usize) -> SystemSpec {
    let mut b = SystemSpec::builder();
    for i in 0..n {
        b = b.source(0.2 + 0.1 * i as f64, i as f64);
    }
    let a: Vec<f64> = (0..m).map(|k| 2.0 + 0.5 * k as f64).collect();
    b.processors(&a).job(100.0).build().unwrap()
}

/// Average nanoseconds per call of `f` over `reps` calls.
fn time_ns<F: FnMut()>(reps: usize, mut f: F) -> f64 {
    let t0 = Instant::now();
    for _ in 0..reps {
        f();
    }
    t0.elapsed().as_nanos() as f64 / reps.max(1) as f64
}

struct MatvecCell {
    cell: String,
    rows: usize,
    vars: usize,
    nnz: usize,
    dense_ns: f64,
    sparse_ns: f64,
    speedup: f64,
}

/// Sparse CSC matvec vs a dense row-major matvec over the identical
/// standardized FE constraint matrix.
fn matvec_cell(n: usize, m: usize, reps: usize) -> MatvecCell {
    let lp = frontend::build_lp(&spec(n, m), &Default::default());
    let slp = SparseLp::build(&lp);
    let (rows, vars) = (slp.num_rows(), slp.num_vars());

    let mut dense = vec![0.0; rows * vars];
    for j in 0..vars {
        for (i, v) in slp.a.col(j) {
            dense[i * vars + j] = v;
        }
    }
    let x: Vec<f64> = (0..vars).map(|j| 1.0 + (j % 7) as f64).collect();
    let mut out = vec![0.0; rows];

    let dense_ns = time_ns(reps, || {
        for (i, o) in out.iter_mut().enumerate() {
            let row = &dense[i * vars..(i + 1) * vars];
            *o = row.iter().zip(&x).map(|(a, b)| a * b).sum();
        }
        std::hint::black_box(&out);
    });
    let sparse_ns = time_ns(reps, || {
        slp.a.matvec_into(&x, &mut out);
        std::hint::black_box(&out);
    });

    MatvecCell {
        cell: format!("fe_n{n}_m{m}"),
        rows,
        vars,
        nnz: slp.a.nnz(),
        dense_ns,
        sparse_ns,
        speedup: dense_ns / sparse_ns.max(1e-9),
    }
}

struct BlockCell {
    width: usize,
    sequential_ms: f64,
    block_ms: f64,
    throughput_ratio: f64,
    columns_retired: usize,
}

/// One panel of `width` job-scaled FE scenarios vs the same scenarios
/// solved sequentially, best-of-`reps` wall clock on both sides.
fn block_cell(base: &SystemSpec, width: usize, opts: &PdhgOptions, reps: usize) -> BlockCell {
    let mut lps = Vec::new();
    for k in 0..width {
        let s = base.with_job(100.0 + 25.0 * k as f64);
        lps.push(frontend::build_lp(&s, &Default::default()));
    }

    let mut seq_ns = f64::INFINITY;
    for _ in 0..reps {
        let t0 = Instant::now();
        for lp in &lps {
            std::hint::black_box(solve_rust(lp, opts).expect("sequential pdhg"));
        }
        seq_ns = seq_ns.min(t0.elapsed().as_nanos() as f64);
    }

    let mut blk_ns = f64::INFINITY;
    let mut retired = 0usize;
    for _ in 0..reps {
        let t0 = Instant::now();
        let blk = solve_block(&lps, opts).expect("block pdhg");
        blk_ns = blk_ns.min(t0.elapsed().as_nanos() as f64);
        retired = blk.columns_retired;
        if std::env::var("DLT_BENCH_ASSERT").is_ok() {
            for (lp, col) in lps.iter().zip(&blk.columns) {
                let seq = solve_rust(lp, opts).expect("parity solve");
                assert!(
                    (col.objective - seq.objective).abs() < 1e-6 * seq.objective.abs().max(1.0),
                    "width {width}: block column drifted from the sequential driver"
                );
            }
        }
        std::hint::black_box(&blk);
    }

    BlockCell {
        width,
        sequential_ms: seq_ns * 1e-6,
        block_ms: blk_ns * 1e-6,
        throughput_ratio: seq_ns / blk_ns.max(1.0),
        columns_retired: retired,
    }
}

fn main() {
    let fast = std::env::var("DLT_BENCH_FAST").is_ok();
    let assert_gates = std::env::var("DLT_BENCH_ASSERT").is_ok();
    let matvec_reps = if fast { 2_000 } else { 20_000 };
    let block_reps = if fast { 2 } else { 4 };
    let sweep_points = if fast { 12 } else { 24 };

    println!("== bench group: pdhg (sparse kernels, block batching, hybrid crossover) ==");

    // --- sparse vs dense matvec on growing FE instances ---
    let matvec_cells: Vec<MatvecCell> = [(2usize, 5usize), (3, 10), (3, 40)]
        .iter()
        .map(|&(n, m)| matvec_cell(n, m, matvec_reps))
        .collect();
    println!(
        "{:<14} {:>6} {:>6} {:>7} {:>12} {:>12} {:>9}",
        "matvec cell", "rows", "vars", "nnz", "dense", "sparse", "speedup"
    );
    for c in &matvec_cells {
        println!(
            "{:<14} {:>6} {:>6} {:>7} {:>10.0}ns {:>10.0}ns {:>8.1}x",
            c.cell, c.rows, c.vars, c.nnz, c.dense_ns, c.sparse_ns, c.speedup
        );
    }
    if assert_gates {
        let largest = matvec_cells.last().expect("at least one matvec cell");
        assert!(
            largest.speedup >= 4.0,
            "sparse matvec only {:.1}x faster than dense on {}",
            largest.speedup,
            largest.cell
        );
    }

    // --- block panels vs sequential PDHG ---
    // Loosened tolerances keep the per-column block counts moderate
    // (and spread, so early retirement engages); the ratio compares
    // identical trajectories on both sides either way.
    let popts = PdhgOptions {
        tol: 1e-5,
        gap_tol: 1e-4,
        max_blocks: if fast { 150 } else { 400 },
        ..Default::default()
    };
    let block_base = spec(2, 8);
    let block_cells: Vec<BlockCell> = [1usize, 4, 16]
        .iter()
        .map(|&w| block_cell(&block_base, w, &popts, block_reps))
        .collect();
    println!(
        "\n{:<12} {:>14} {:>14} {:>12} {:>9}",
        "block width", "sequential", "block", "throughput", "retired"
    );
    for c in &block_cells {
        println!(
            "{:<12} {:>12.2}ms {:>12.2}ms {:>11.2}x {:>9}",
            c.width, c.sequential_ms, c.block_ms, c.throughput_ratio, c.columns_retired
        );
    }
    if assert_gates {
        let wide = block_cells.last().expect("width-16 cell");
        assert!(
            wide.throughput_ratio >= 2.0,
            "block-of-16 only {:.2}x sequential throughput",
            wide.throughput_ratio
        );
    }

    // --- hybrid crossover sweep vs cold simplex sweep ---
    let s = spec(2, 5);
    let jobs: Vec<f64> = (0..sweep_points).map(|k| 100.0 + 10.0 * k as f64).collect();

    let mut hybrid_session = Solver::new().backend(Backend::Hybrid).build();
    let mut cleanup_pivots = 0usize;
    let mut stage_blocks = 0usize;
    let t0 = Instant::now();
    for &j in &jobs {
        let resp = hybrid_session
            .solve(&SolveRequest::new(Family::Frontend, s.with_job(j)))
            .expect("hybrid solve");
        let d = resp.diagnostics.pdhg.as_ref().expect("hybrid first-order diagnostics");
        cleanup_pivots += d.crossover_pivots;
        stage_blocks += d.blocks;
    }
    let hybrid_ms = t0.elapsed().as_nanos() as f64 * 1e-6;

    let mut cold_session = Solver::new().warm_start(false).build();
    let mut cold_pivots = 0usize;
    let t0 = Instant::now();
    for &j in &jobs {
        let resp = cold_session
            .solve(&SolveRequest::new(Family::Frontend, s.with_job(j)))
            .expect("cold simplex solve");
        let d = &resp.diagnostics;
        cold_pivots += d.iterations + d.phase1_iterations + d.dual_iterations;
    }
    let cold_ms = t0.elapsed().as_nanos() as f64 * 1e-6;

    let hybrid_note = format!(
        "hybrid sweep ({sweep_points} jobs): {cleanup_pivots} cleanup pivots \
         ({stage_blocks} pdhg blocks, {hybrid_ms:.2}ms) vs cold simplex \
         {cold_pivots} pivots ({cold_ms:.2}ms)"
    );
    println!("\n   note: {hybrid_note}");
    if assert_gates {
        assert!(
            cleanup_pivots <= cold_pivots,
            "hybrid cleanup spent {cleanup_pivots} pivots, cold simplex {cold_pivots}"
        );
    }

    // --- adaptive refinement vs a uniform fine grid ---
    let coarse: Vec<f64> = (1..=6).map(|k| k as f64).collect();
    let (threshold, tol) = (0.05, 0.05);
    let axis = ContinuousAxis::LinkScale;
    let r = refine(&s, TimingModel::FrontEnd, axis, &coarse, threshold, tol).expect("refine");
    let span = coarse.last().unwrap() - coarse.first().unwrap();
    // A uniform grid resolving the knee to the same bracket width
    // (`tol` x one coarse window, the windows here being unit-width).
    let fine_grid_equivalent = (span / tol).ceil() as usize + 1;
    let (knee_lo, knee_hi) = r.knee.expect("knee exists on this axis");
    let refine_note = format!(
        "refine (links 1..6): knee [{knee_lo:.4}, {knee_hi:.4}] in {} solves vs \
         {fine_grid_equivalent}-point uniform grid",
        r.solves
    );
    println!("   note: {refine_note}");
    if assert_gates {
        assert!(
            r.solves < fine_grid_equivalent,
            "refinement spent {} solves, no better than the {fine_grid_equivalent}-point grid",
            r.solves
        );
    }

    // --- JSON artifact ---
    let matvec_json: Vec<Json> = matvec_cells
        .iter()
        .map(|c| {
            Json::Object(vec![
                ("cell".into(), Json::Str(c.cell.clone())),
                ("rows".into(), Json::Num(c.rows as f64)),
                ("vars".into(), Json::Num(c.vars as f64)),
                ("nnz".into(), Json::Num(c.nnz as f64)),
                ("dense_ns".into(), Json::Num(c.dense_ns)),
                ("sparse_ns".into(), Json::Num(c.sparse_ns)),
                ("speedup".into(), Json::Num(c.speedup)),
            ])
        })
        .collect();
    let block_json: Vec<Json> = block_cells
        .iter()
        .map(|c| {
            Json::Object(vec![
                ("width".into(), Json::Num(c.width as f64)),
                ("sequential_ms".into(), Json::Num(c.sequential_ms)),
                ("block_ms".into(), Json::Num(c.block_ms)),
                ("throughput_ratio".into(), Json::Num(c.throughput_ratio)),
                ("columns_retired".into(), Json::Num(c.columns_retired as f64)),
            ])
        })
        .collect();
    let notes = Json::Array(vec![Json::Str(hybrid_note), Json::Str(refine_note)]);
    let doc = Json::Object(vec![
        ("group".into(), Json::Str("pdhg".into())),
        (
            "instance".into(),
            Json::Str(format!(
                "fe scheduling LPs, {sweep_points}-point hybrid sweep, \
                 block budget {} blocks",
                popts.max_blocks
            )),
        ),
        ("matvec_cells".into(), Json::Array(matvec_json)),
        ("block_cells".into(), Json::Array(block_json)),
        (
            "hybrid".into(),
            Json::Object(vec![
                ("sweep_points".into(), Json::Num(sweep_points as f64)),
                ("hybrid_cleanup_pivots".into(), Json::Num(cleanup_pivots as f64)),
                ("hybrid_stage_blocks".into(), Json::Num(stage_blocks as f64)),
                ("cold_simplex_pivots".into(), Json::Num(cold_pivots as f64)),
                ("hybrid_ms".into(), Json::Num(hybrid_ms)),
                ("cold_ms".into(), Json::Num(cold_ms)),
            ]),
        ),
        (
            "refine".into(),
            Json::Object(vec![
                ("coarse_points".into(), Json::Num(coarse.len() as f64)),
                ("threshold".into(), Json::Num(threshold)),
                ("tol".into(), Json::Num(tol)),
                ("refine_solves".into(), Json::Num(r.solves as f64)),
                ("fine_grid_equivalent".into(), Json::Num(fine_grid_equivalent as f64)),
                ("knee_lo".into(), Json::Num(knee_lo)),
                ("knee_hi".into(), Json::Num(knee_hi)),
            ]),
        ),
        ("notes".into(), notes),
    ]);
    if let Ok(dir) = std::env::var("DLT_BENCH_JSON_DIR") {
        std::fs::create_dir_all(&dir).expect("create bench json dir");
        let path = std::path::Path::new(&dir).join("BENCH_pdhg_hybrid.json");
        std::fs::write(&path, doc.to_string_pretty()).expect("write BENCH_pdhg_hybrid.json");
        println!("   wrote {}", path.display());
    }
}
