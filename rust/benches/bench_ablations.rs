//! Ablations for the design choices DESIGN.md calls out, plus the
//! paper's §8 future-work extensions measured on the paper's own
//! parameters.
//!
//! 1. eq. 5 variant (`k ≤ j−1` prose vs `k ≤ j` summary block) — how
//!    much does the ambiguous constraint move `T_f`?
//! 2. eq. 12 (`TF_{i−1,1} ≥ R_i` keep-source-busy) — cost of the
//!    constraint, and when it turns instances infeasible.
//! 3. §8 concurrent distribution vs the paper's sequential protocol.
//! 4. §8 multi-job pipelining vs serial job execution.

use dlt::benchkit::{Bencher, Reporter};
use dlt::dlt::frontend::FeOptions;
use dlt::dlt::no_frontend::NfeOptions;
use dlt::dlt::concurrent::{ConcurrentOptions, Mode};
use dlt::dlt::multi_job;
use dlt::pipeline;
use dlt::experiments::params;

fn main() {
    let b = Bencher::from_env();
    let mut rep = Reporter::new("ablations (design choices + §8 extensions)");

    // --- 1. eq. 5 variant ---
    let t5 = params::table5();
    println!("\n-- eq.5 finish-sum variant (Table 5, FE) --");
    println!("{:>4} {:>14} {:>14} {:>8}", "m", "tf (k<=j-1)", "tf (k<=j)", "delta%");
    for m in [1usize, 5, 10, 20] {
        let sub = t5.with_m_processors(m);
        let a = pipeline::solve(&FeOptions::default(), &sub).unwrap().makespan;
        let c = pipeline::solve(
            &FeOptions { finish_sum_includes_j: true, ..Default::default() },
            &sub,
        )
        .unwrap()
        .makespan;
        println!("{m:>4} {a:>14.4} {c:>14.4} {:>8.2}", (c / a - 1.0) * 100.0);
    }

    // --- 2. eq. 12 keep-source-busy ---
    println!("\n-- eq.12 source-busy constraint (Table 2-like, NFE) --");
    println!("{:>8} {:>14} {:>14}", "R2", "tf (with)", "tf (without)");
    for r2 in [2.0f64, 5.0, 10.0, 15.0] {
        let spec = dlt::model::SystemSpec::builder()
            .source(0.2, 0.0)
            .source(0.2, r2)
            .processors(&[2.0, 3.0, 4.0])
            .job(100.0)
            .build()
            .unwrap();
        let with = pipeline::solve(&NfeOptions::default(), &spec)
            .map(|s| format!("{:.4}", s.makespan))
            .unwrap_or_else(|_| "infeasible".into());
        let without = pipeline::solve(
            &NfeOptions { drop_source_busy_constraint: true },
            &spec,
        )
        .map(|s| format!("{:.4}", s.makespan))
        .unwrap_or_else(|_| "infeasible".into());
        println!("{r2:>8} {with:>14} {without:>14}");
    }

    // --- 3. §8 concurrent vs sequential distribution ---
    let t3 = params::table3();
    println!("\n-- §8 concurrent distribution vs sequential (Table 3, NFE) --");
    println!(
        "{:>4} {:>14} {:>14} {:>14} {:>10}",
        "m", "sequential", "proportional", "staggered", "speedup"
    );
    for m in [2usize, 5, 10, 20] {
        let sub = t3.with_m_processors(m);
        let seq = pipeline::solve(&NfeOptions::default(), &sub).unwrap().makespan;
        let prop = pipeline::solve(&ConcurrentOptions { mode: Mode::Proportional }, &sub)
            .unwrap()
            .makespan;
        let stag = pipeline::solve(&ConcurrentOptions { mode: Mode::Staggered }, &sub)
            .unwrap()
            .makespan;
        println!("{m:>4} {seq:>14.4} {prop:>14.4} {stag:>14.4} {:>9.2}x", seq / stag);
    }
    let sub = t3.with_m_processors(10);
    rep.report("solve_concurrent_n3_m10", b.bench_val(|| pipeline::solve(&ConcurrentOptions::default(), &sub).unwrap()));
    rep.report("solve_sequential_n3_m10", b.bench_val(|| pipeline::solve(&NfeOptions::default(), &sub).unwrap()));

    // --- 4. §8 multi-job pipelining ---
    println!("\n-- §8 multi-job FIFO pipeline vs serial (FE) --");
    // Comm-heavy regime (G comparable to effective compute rate):
    // pipelining overlaps job k+1's distribution under job k's compute.
    let spec = dlt::model::SystemSpec::builder()
        .source(0.30, 0.0)
        .source(0.40, 1.0)
        .processors(&[1.0, 1.5, 2.0, 2.5])
        .job(1.0)
        .build()
        .unwrap();
    for (count, gap) in [(4usize, 5.0f64), (8, 2.0)] {
        let jobs = multi_job::synth_jobs(count, gap, 30.0, 11);
        let r = multi_job::schedule_fifo(&spec, &jobs).unwrap();
        println!(
            "{count} jobs (mean gap {gap}): pipeline makespan {:.2} vs serial {:.2} ({:.2}x), mean sojourn {:.2}",
            r.makespan,
            r.serial_makespan,
            r.serial_makespan / r.makespan,
            r.mean_sojourn
        );
    }
    let jobs = multi_job::synth_jobs(6, 3.0, 30.0, 11);
    rep.report(
        "pipeline_6_jobs",
        b.bench_val(|| multi_job::schedule_fifo(&spec, &jobs).unwrap()),
    );
    rep.finish();
}
