//! Bench: Table 5 / Figs. 16–18 — cost / finish time / gradient sweep.

use dlt::benchkit::{Bencher, Reporter};
use dlt::cost::TradeoffTable;
use dlt::experiments::{params, series};

fn main() {
    let b = Bencher::from_env();
    let mut rep = Reporter::new("fig16_18 (trade-off sweep, Table 5)");

    let spec = params::table5();
    rep.report("tradeoff_sweep_m1_to_20", b.bench_val(|| TradeoffTable::sweep(&spec).unwrap()));
    rep.finish();

    let (f16, f17, f18) = series::fig16_17_18().unwrap();
    println!("{}", f16.render_text());
    println!("{}", f17.render_text());
    println!("{}", f18.render_text());
}
