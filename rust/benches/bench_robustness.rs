//! Bench: the fail-operational tier — what robustness costs.
//!
//! Three sections:
//!
//! - **deadline overhead** — the same warm-session job sweep with an
//!   unbounded budget vs a generous 60 s deadline. The budget check is
//!   amortized (`iterations & 63 == 0`), so the bounded sweep must stay
//!   within 2 % of the unbounded one (the schema gate).
//! - **ladder engage** — a cold solve vs the same solve handed a
//!   corrupted (singular / wrong-shape) warm basis: the recovery path
//!   must fall back cold, land on the same optimum, and record
//!   `warm_fallback_cold` in `recovery_events` (count gated >= 1).
//! - **deadline honored** — a PDHG solve that cannot converge
//!   (`tol = 0`) under a real wall-clock deadline: the typed
//!   `DeadlineExceeded` must arrive within 2x the deadline.
//!
//! With `DLT_BENCH_JSON_DIR=dir` the results land in
//! `dir/BENCH_robustness.json`; `DLT_BENCH_FAST=1` trims repetitions;
//! `DLT_BENCH_ASSERT=1` turns the gates into in-process panics (CI
//! leaves it unset so the JSON artifact survives a regression and the
//! python step stays the single gate).

use dlt::api::{Family, SolveRequest, Solver};
use dlt::config::json::Json;
use dlt::dlt::frontend;
use dlt::error::Error;
use dlt::lp::{solve_warm, solve_with, Basis, SimplexOptions};
use dlt::model::SystemSpec;
use dlt::pipeline::{self, Backend, PipelineOptions};
use std::time::Instant;

fn spec(n: usize, m: usize) -> SystemSpec {
    let mut b = SystemSpec::builder();
    for i in 0..n {
        b = b.source(0.2 + 0.1 * i as f64, i as f64);
    }
    let a: Vec<f64> = (0..m).map(|k| 2.0 + 0.5 * k as f64).collect();
    b.processors(&a).job(100.0).build().unwrap()
}

/// Wall-clock milliseconds for one warm-session sweep of `solves`
/// job-scaled requests, every request carrying `timeout_ms`.
fn sweep_ms(s: &SystemSpec, solves: usize, timeout_ms: Option<u64>) -> f64 {
    let mut session = Solver::new().build();
    // Warm the cache outside the timed region.
    for k in 0..4 {
        let mut req = SolveRequest::new(Family::Frontend, s.with_job(100.0 + k as f64));
        req.options.timeout_ms = timeout_ms;
        session.solve(&req).expect("warmup solve");
    }
    let t0 = Instant::now();
    for k in 0..solves {
        let mut req =
            SolveRequest::new(Family::Frontend, s.with_job(100.0 + (k % 8) as f64));
        req.options.timeout_ms = timeout_ms;
        std::hint::black_box(session.solve(&req).expect("sweep solve"));
    }
    t0.elapsed().as_nanos() as f64 * 1e-6
}

fn main() {
    let fast = std::env::var("DLT_BENCH_FAST").is_ok();
    let assert_gates = std::env::var("DLT_BENCH_ASSERT").is_ok();
    let solves = if fast { 400 } else { 2_000 };
    let rounds = if fast { 3 } else { 5 };

    println!("== bench group: robustness (deadline budgets, recovery ladder, degradation) ==");

    // --- deadline-check overhead on the warm hot path ---
    // Interleaved best-of-`rounds` on both sides so drift hits them
    // equally; the gate compares the two minima.
    let s = spec(2, 6);
    let (mut baseline_ms, mut budgeted_ms) = (f64::INFINITY, f64::INFINITY);
    for _ in 0..rounds {
        baseline_ms = baseline_ms.min(sweep_ms(&s, solves, None));
        budgeted_ms = budgeted_ms.min(sweep_ms(&s, solves, Some(60_000)));
    }
    let overhead_pct = (budgeted_ms - baseline_ms) / baseline_ms * 100.0;
    println!(
        "deadline overhead: {solves} warm solves, unbounded {baseline_ms:.2}ms vs \
         60s-budget {budgeted_ms:.2}ms ({overhead_pct:+.2}%)"
    );
    if assert_gates {
        assert!(
            overhead_pct <= 2.0,
            "deadline checks cost {overhead_pct:.2}% on the warm hot path (budget: <= 2%)"
        );
    }

    // --- recovery-ladder engagement latency ---
    let lp = frontend::build_lp(&spec(3, 10), &Default::default());
    let opts = SimplexOptions::default();
    let reps = if fast { 40 } else { 200 };
    let (mut cold_ns, mut engage_ns) = (f64::INFINITY, f64::INFINITY);
    let garbage = Basis { cols: vec![0, 0, 0, 0] };
    let mut events = 0usize;
    for _ in 0..rounds {
        let t0 = Instant::now();
        for _ in 0..reps {
            std::hint::black_box(solve_with(&lp, &opts).expect("cold solve"));
        }
        cold_ns = cold_ns.min(t0.elapsed().as_nanos() as f64 / reps as f64);
        let t0 = Instant::now();
        for _ in 0..reps {
            let sol = solve_warm(&lp, &opts, Some(&garbage)).expect("recovered solve");
            events = sol.recovery_events.len();
            std::hint::black_box(sol);
        }
        engage_ns = engage_ns.min(t0.elapsed().as_nanos() as f64 / reps as f64);
    }
    let (cold_ms, engage_ms) = (cold_ns * 1e-6, engage_ns * 1e-6);
    println!(
        "ladder engage: cold {cold_ms:.3}ms vs corrupted-warm-basis {engage_ms:.3}ms \
         ({events} recovery event(s) recorded)"
    );
    if assert_gates {
        assert!(events >= 1, "corrupted warm basis recorded no recovery events");
    }

    // --- deadline honored under a diverging first-order solve ---
    let timeout_ms: u64 = if fast { 30 } else { 50 };
    let heavy = spec(3, 40);
    let popts = PipelineOptions {
        backend: Backend::Pdhg,
        timeout_ms: Some(timeout_ms),
        pdhg: dlt::pdhg::PdhgOptions {
            tol: 0.0,
            gap_tol: 0.0,
            max_blocks: usize::MAX / 2,
            ..Default::default()
        },
        ..PipelineOptions::default()
    };
    let t0 = Instant::now();
    let verdict = pipeline::solve_full(&frontend::FeOptions::default(), &heavy, &popts, None, None);
    let observed_ms = t0.elapsed().as_nanos() as f64 * 1e-6;
    let typed = matches!(verdict, Err(Error::DeadlineExceeded { .. }));
    let within_factor = observed_ms / timeout_ms as f64;
    println!(
        "deadline honored: {timeout_ms}ms budget on a non-converging pdhg solve -> \
         typed={typed} after {observed_ms:.1}ms ({within_factor:.2}x the deadline)"
    );
    if assert_gates {
        assert!(typed, "non-converging solve under deadline did not return DeadlineExceeded");
        assert!(
            within_factor <= 2.0,
            "deadline honored only within {within_factor:.2}x (budget: <= 2x)"
        );
    }

    // --- JSON artifact ---
    let doc = Json::Object(vec![
        ("group".into(), Json::Str("robustness".into())),
        (
            "instance".into(),
            Json::Str(format!(
                "fe warm sweep ({solves} solves), corrupted-basis recovery, \
                 {timeout_ms}ms pdhg deadline"
            )),
        ),
        (
            "deadline_overhead".into(),
            Json::Object(vec![
                ("solves".into(), Json::Num(solves as f64)),
                ("baseline_ms".into(), Json::Num(baseline_ms)),
                ("budgeted_ms".into(), Json::Num(budgeted_ms)),
                ("overhead_pct".into(), Json::Num(overhead_pct)),
            ]),
        ),
        (
            "ladder".into(),
            Json::Object(vec![
                ("cold_ms".into(), Json::Num(cold_ms)),
                ("engage_ms".into(), Json::Num(engage_ms)),
                ("recovery_events_count".into(), Json::Num(events as f64)),
            ]),
        ),
        (
            "deadline_honored".into(),
            Json::Object(vec![
                ("timeout_ms".into(), Json::Num(timeout_ms as f64)),
                ("observed_ms".into(), Json::Num(observed_ms)),
                ("within_factor".into(), Json::Num(within_factor)),
                ("typed_error".into(), Json::Bool(typed)),
            ]),
        ),
    ]);
    if let Ok(dir) = std::env::var("DLT_BENCH_JSON_DIR") {
        std::fs::create_dir_all(&dir).expect("create bench json dir");
        let path = std::path::Path::new(&dir).join("BENCH_robustness.json");
        std::fs::write(&path, doc.to_string_pretty()).expect("write BENCH_robustness.json");
        println!("   wrote {}", path.display());
    }
}
