//! Bench: Fig. 13 — finish time vs processors for different job sizes
//! (front-ends). The LP is job-size independent in structure, so the
//! solve cost is flat across J — the bench demonstrates that too.

use dlt::benchkit::{Bencher, Reporter};
use dlt::dlt::frontend::FeOptions;
use dlt::pipeline;
use dlt::experiments::{params, run};

fn main() {
    let b = Bencher::from_env();
    let mut rep = Reporter::new("fig13 (T_f vs M for J=100/300/500, FE)");

    let spec = params::table3();
    for &j in params::FIG13_JOB_SIZES {
        let sub = spec.with_job(j).with_m_processors(10);
        rep.report(
            &format!("solve_fe_m10_J{j}"),
            b.bench_val(|| pipeline::solve(&FeOptions::default(), &sub).unwrap()),
        );
    }
    rep.finish();
    println!("{}", run("fig13").unwrap().render_text());
}
