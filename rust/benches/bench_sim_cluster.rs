//! Bench: discrete-event simulator throughput and cluster overhead.
//!
//! §Perf harness for Layer 3 beyond the LP: the DES must stay far off
//! the critical path (millions of events/s), and the cluster's
//! realized-vs-predicted error is the end-to-end fidelity metric.

use dlt::benchkit::{Bencher, Reporter};
use dlt::cluster::{run_cluster, ClusterConfig, Compute};
use dlt::dlt::no_frontend::NfeOptions;
use dlt::pipeline;
use dlt::model::SystemSpec;
use dlt::sim::{simulate, SimOptions};

fn spec(n: usize, m: usize) -> SystemSpec {
    let mut b = SystemSpec::builder();
    for i in 0..n {
        b = b.source(0.4 + 0.02 * i as f64, 0.2 * i as f64);
    }
    let a: Vec<f64> = (0..m).map(|k| 1.0 + 0.05 * k as f64).collect();
    b.processors(&a).job(100.0).build().unwrap()
}

fn main() {
    let b = Bencher::from_env();
    let mut rep = Reporter::new("sim + cluster");

    for (n, m) in [(2usize, 8usize), (5, 32), (10, 64)] {
        let s = spec(n, m);
        // Uniform beta is fine for engine-throughput measurement.
        let beta = vec![s.job / (n * m) as f64; n * m];
        let events = (n * m + m) as f64;
        let r = b.bench_val(|| simulate(&s, &beta, &SimOptions::default()));
        let evps = events / (r.ns.median * 1e-9);
        rep.report(&format!("des_n{n}_m{m} ({:.1}M events/s)", evps / 1e6), r);
    }

    // One real cluster run (wall-clock bound; report, don't loop).
    let s = spec(2, 4);
    let sched = pipeline::solve(&NfeOptions::default(), &s).unwrap();
    let cfg = ClusterConfig { time_scale: 0.0005, compute: Compute::Modeled, fe_splits: 16 };
    let t0 = std::time::Instant::now();
    let report = run_cluster(&s, &sched, &cfg).unwrap();
    rep.note(&format!(
        "cluster 2x4: predicted {:.3}, realized {:.3} ({:+.2}% err), wall {:?} (single run, t={:?})",
        report.predicted_makespan,
        report.realized_makespan,
        report.relative_error * 100.0,
        report.wall,
        t0.elapsed()
    ));
    rep.finish();
}
