//! Bench: Tables 1–2 / Figs. 10–11 — the paper's numerical tests.
//!
//! Regenerates both numerical-test tables (printing the same rows the
//! paper plots) and times the solves.

use dlt::benchkit::{Bencher, Reporter};
use dlt::dlt::frontend::FeOptions;
use dlt::dlt::no_frontend::NfeOptions;
use dlt::pipeline;
use dlt::experiments::{params, run};

fn main() {
    let b = Bencher::from_env();
    let mut rep = Reporter::new("numerical_tests (Tables 1-2, Figs 10-11)");

    let t1 = params::table1();
    rep.report("solve_table1_frontend", b.bench_val(|| pipeline::solve(&FeOptions::default(), &t1).unwrap()));
    let t2 = params::table2();
    rep.report("solve_table2_no_frontend", b.bench_val(|| pipeline::solve(&NfeOptions::default(), &t2).unwrap()));
    rep.finish();

    // The paper's data series.
    for fig in ["fig10", "fig11"] {
        println!("{}", run(fig).unwrap().render_text());
    }
}
