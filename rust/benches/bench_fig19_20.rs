//! Bench: Figs. 19–20 — budget-intersection analysis (the advisor).

use dlt::benchkit::{Bencher, Reporter};
use dlt::cost::{advise, Budgets, TradeoffTable};
use dlt::experiments::{params, run};

fn main() {
    let b = Bencher::from_env();
    let mut rep = Reporter::new("fig19_20 (budget advisor)");

    let spec = params::table5();
    let sweep = TradeoffTable::sweep(&spec).unwrap();
    let budgets = Budgets {
        cost: Some(sweep.at(12).cost),
        time: Some(sweep.at(6).tf),
        gradient_threshold: 0.06,
    };
    rep.report("advise_given_sweep", b.bench_val(|| advise(&sweep, &budgets)));
    rep.finish();

    println!("{}", run("fig19").unwrap().render_text());
    println!("{}", run("fig20").unwrap().render_text());
}
