//! Bench: the hypersparse simplex hot path — sparse FTRAN/BTRAN
//! kernels, candidate-list partial pricing, and the scratch-pooled
//! warm sweep — against the dense baselines they replaced.
//!
//! Four sections:
//!
//! - **micro kernels** — one factorized sparse basis per strategy
//!   (eta file, Forrest–Tomlin, Markowitz, Bartels–Golub), timing the
//!   dense `ftran`/`btran` entry points (for `product_form_eta` /
//!   `markowitz` this is the genuinely dense legacy implementation:
//!   dense LU solve + full eta passes) against
//!   `ftran_sparse`/`btran_sparse` on the near-unit right-hand sides
//!   the revised simplex actually produces. Also records
//!   `storage_nnz` vs the `2m²` a dense L/U pair would pin — the
//!   peak-basis-memory story.
//! - **gp kernels** — the Gilbert–Peierls symbolic DFS against the
//!   full column-sweep scan on the *same* LU factor and right-hand
//!   side, with the deterministic `last_solve_work` node counter
//!   alongside wall time.
//! - **warm sweep cells** — a job-size sweep through one `dlt::api`
//!   session (the production shape) per configuration: the dense
//!   tableau (the pre-PR-1 dense baseline cell), revised + full
//!   Dantzig pricing (the PR-4 configuration), revised + partial
//!   pricing, and the Forrest–Tomlin vs Bartels–Golub update-file
//!   race, on the widest grid instance.
//! - **cold solves** per cell for the long-pivot story.
//!
//! With `DLT_BENCH_JSON_DIR=dir` the results land in
//! `dir/BENCH_hypersparse.json`; `DLT_BENCH_FAST=1` shrinks the
//! instance for CI smoke runs; `DLT_BENCH_ASSERT=1` turns the
//! regression guards on (CI fails if the sparse kernels or the sparse
//! warm sweep regress behind their dense baseline cells).

use dlt::api::{Family, SolveRequest, Solver};
use dlt::config::json::Json;
use dlt::linalg::{LuFactors, SolveMode, SparseMatrix, SparseVector};
use dlt::lp::factorization::{BasisFactorization, Factorization};
use dlt::lp::{Pricing, SimplexOptions};
use dlt::model::SystemSpec;
use dlt::pipeline::Backend;
use std::time::Instant;

fn spec(n: usize, m: usize) -> SystemSpec {
    let mut b = SystemSpec::builder();
    for i in 0..n {
        b = b.source(0.5 + 0.01 * i as f64, i as f64 * 0.5);
    }
    let a: Vec<f64> = (0..m).map(|k| 1.1 + 0.1 * k as f64).collect();
    b.processors(&a).job(100.0).build().unwrap()
}

/// Timing-chain-shaped sparse basis: ~3 entries per column.
fn chain_basis(m: usize) -> SparseMatrix {
    let mut trips: Vec<(usize, usize, f64)> = Vec::new();
    for j in 0..m {
        trips.push((j, j, 2.0 + 0.01 * (j % 7) as f64));
        if j + 1 < m {
            trips.push((j + 1, j, -0.5 - 0.01 * (j % 5) as f64));
        }
        if j >= 4 {
            trips.push((j - 4, j, 0.25));
        }
    }
    SparseMatrix::from_triplets(m, m, &trips)
}

struct Micro {
    strategy: Factorization,
    /// True when the strategy's dense entry points are adapters over
    /// the sparse kernels (Forrest–Tomlin): the "dense" timing then
    /// measures adapter overhead, not an independent dense kernel.
    dense_is_adapter: bool,
    ftran_dense_ns: f64,
    ftran_sparse_ns: f64,
    btran_dense_ns: f64,
    btran_sparse_ns: f64,
    storage_nnz: usize,
    dense_equivalent: usize,
}

fn micro_kernels(m: usize, reps: usize) -> Vec<Micro> {
    let basis = chain_basis(m);
    let mut out = Vec::new();
    for strategy in [
        Factorization::ProductFormEta,
        Factorization::ForrestTomlin,
        Factorization::Markowitz,
        Factorization::BartelsGolub,
    ] {
        let mut f: Box<dyn BasisFactorization> = strategy.build(m);
        f.refactorize(&basis).expect("chain basis factorizes");
        // A few updates so the eta file / spike chain is exercised.
        let mut w = SparseVector::with_dim(m);
        for k in 0..24.min(m) {
            let q = (17 * k + 5) % m;
            w.clear();
            w.set(q, 1.25);
            if q + 2 < m {
                w.set(q + 2, -0.75);
            }
            f.ftran_sparse(&mut w);
            let r = w
                .indices()
                .iter()
                .copied()
                .max_by(|&a, &b| w.get(a).abs().partial_cmp(&w.get(b).abs()).unwrap())
                .unwrap();
            if w.get(r).abs() < 1e-6 {
                continue;
            }
            f.update(r, &w).expect("bench update");
        }

        // The near-unit RHS the simplex produces (an entering DLT
        // column has a handful of nonzeros).
        let mut rhs = vec![0.0; m];
        rhs[m / 3] = 1.0;
        rhs[m / 2] = -0.5;
        let mut dense_out = vec![0.0; m];
        let mut sv = SparseVector::with_dim(m);

        let t0 = Instant::now();
        for _ in 0..reps {
            f.ftran(&rhs, &mut dense_out);
        }
        let ftran_dense_ns = t0.elapsed().as_nanos() as f64 / reps as f64;
        let t0 = Instant::now();
        for _ in 0..reps {
            sv.set_from_dense(&rhs);
            f.ftran_sparse(&mut sv);
        }
        let ftran_sparse_ns = t0.elapsed().as_nanos() as f64 / reps as f64;

        let t0 = Instant::now();
        for _ in 0..reps {
            f.btran(&rhs, &mut dense_out);
        }
        let btran_dense_ns = t0.elapsed().as_nanos() as f64 / reps as f64;
        let t0 = Instant::now();
        for _ in 0..reps {
            sv.set_from_dense(&rhs);
            f.btran_sparse(&mut sv);
        }
        let btran_sparse_ns = t0.elapsed().as_nanos() as f64 / reps as f64;

        out.push(Micro {
            strategy,
            dense_is_adapter: matches!(
                strategy,
                Factorization::ForrestTomlin | Factorization::BartelsGolub
            ),
            ftran_dense_ns,
            ftran_sparse_ns,
            btran_dense_ns,
            btran_sparse_ns,
            storage_nnz: f.storage_nnz(),
            dense_equivalent: 2 * m * m,
        });
    }
    out
}

/// Gilbert–Peierls symbolic DFS vs the full column-sweep scan on the
/// same LU factor and right-hand side: per-solve wall time plus the
/// exact `last_solve_work` counter (DFS: reach sizes; scan: `2n`).
struct GpCell {
    kernel: &'static str,
    dfs_ns: f64,
    scan_ns: f64,
    dfs_work: usize,
    scan_work: usize,
    result_nnz: usize,
}

fn gp_kernels(m: usize, reps: usize) -> Vec<GpCell> {
    let basis = chain_basis(m);
    let mut lu = LuFactors::factor_csc(&basis).expect("chain basis factorizes");
    let mut v = SparseVector::with_dim(m);
    let mut tmp = SparseVector::with_dim(m);
    let mut out = Vec::new();
    for kernel in ["ftran", "btran"] {
        let mut cell = GpCell {
            kernel,
            dfs_ns: 0.0,
            scan_ns: 0.0,
            dfs_work: 0,
            scan_work: 0,
            result_nnz: 0,
        };
        for mode in [SolveMode::Dfs, SolveMode::Scan] {
            lu.set_solve_mode(mode);
            let t0 = Instant::now();
            for _ in 0..reps {
                // A tail-heavy 2-nonzero RHS (the shape a late entering
                // DLT column produces): its topological closure is a
                // small fraction of the factor.
                v.clear();
                v.set(m - 2, 1.0);
                v.set(m - 1, -0.5);
                if kernel == "ftran" {
                    lu.solve_sparse(&mut v, &mut tmp);
                } else {
                    lu.solve_transpose_sparse(&mut v, &mut tmp);
                }
            }
            let ns = t0.elapsed().as_nanos() as f64 / reps as f64;
            if mode == SolveMode::Dfs {
                cell.dfs_ns = ns;
                cell.dfs_work = lu.last_solve_work();
                cell.result_nnz = v.nnz();
            } else {
                cell.scan_ns = ns;
                cell.scan_work = lu.last_solve_work();
            }
        }
        out.push(cell);
    }
    out
}

struct Cell {
    label: &'static str,
    backend: Backend,
    factorization: Factorization,
    pricing: Pricing,
    cold_ms: f64,
    cold_iterations: usize,
    sweep_ms: f64,
    sweep_iterations: usize,
    candidate_hits: usize,
    candidate_refreshes: usize,
    avg_ftran_nnz: f64,
}

fn sweep_cell(
    label: &'static str,
    backend: Backend,
    factorization: Factorization,
    pricing: Pricing,
    base: &SystemSpec,
    points: usize,
) -> Cell {
    let simplex = SimplexOptions { factorization, pricing, ..SimplexOptions::default() };

    let mut cold_session =
        Solver::new().backend(backend).warm_start(false).simplex(simplex.clone()).build();
    let t0 = Instant::now();
    let cold = cold_session
        .solve(&SolveRequest::new(Family::NoFrontend, base.clone()))
        .expect("cold solve");
    let cold_ms = t0.elapsed().as_secs_f64() * 1e3;

    let mut session = Solver::new().backend(backend).simplex(simplex).build();
    let mut sweep_iterations = 0usize;
    let mut candidate_hits = 0usize;
    let mut candidate_refreshes = 0usize;
    let mut nnz_acc = 0.0f64;
    let mut nnz_n = 0usize;
    let t0 = Instant::now();
    for k in 0..points {
        let sub = base.with_job(100.0 + 10.0 * k as f64);
        let resp = session
            .solve(&SolveRequest::new(Family::NoFrontend, sub))
            .expect("sweep solve");
        sweep_iterations += resp.diagnostics.iterations;
        candidate_hits += resp.diagnostics.candidate_hits;
        candidate_refreshes += resp.diagnostics.candidate_refreshes;
        if resp.diagnostics.avg_ftran_nnz > 0.0 {
            nnz_acc += resp.diagnostics.avg_ftran_nnz;
            nnz_n += 1;
        }
    }
    let sweep_ms = t0.elapsed().as_secs_f64() * 1e3;

    Cell {
        label,
        backend,
        factorization,
        pricing,
        cold_ms,
        cold_iterations: cold.diagnostics.iterations,
        sweep_ms,
        sweep_iterations,
        candidate_hits,
        candidate_refreshes,
        avg_ftran_nnz: if nnz_n > 0 { nnz_acc / nnz_n as f64 } else { 0.0 },
    }
}

fn main() {
    let fast = std::env::var("DLT_BENCH_FAST").is_ok();
    let assert_gates = std::env::var("DLT_BENCH_ASSERT").is_ok();
    let (n, m) = if fast { (3usize, 10usize) } else { (3, 24) };
    let sweep_points = if fast { 8 } else { 24 };
    let micro_m = if fast { 60 } else { 240 };
    let micro_reps = if fast { 400 } else { 2000 };
    let base = spec(n, m);

    println!("== bench group: hypersparse (kernels + partial pricing + warm sweeps) ==");

    // --- micro kernels ---
    let micro = micro_kernels(micro_m, micro_reps);
    println!(
        "{:<18} {:>14} {:>14} {:>14} {:>14} {:>12} {:>12}",
        "kernel (m)",
        "ftran_dense",
        "ftran_sparse",
        "btran_dense",
        "btran_sparse",
        "nnz",
        "dense_2m2"
    );
    for mc in &micro {
        println!(
            "{:<18} {:>12.0}ns {:>12.0}ns {:>12.0}ns {:>12.0}ns {:>12} {:>12}{}",
            mc.strategy.as_str(),
            mc.ftran_dense_ns,
            mc.ftran_sparse_ns,
            mc.btran_dense_ns,
            mc.btran_sparse_ns,
            mc.storage_nnz,
            mc.dense_equivalent,
            if mc.dense_is_adapter { "   (dense = adapter overhead)" } else { "" }
        );
    }

    // --- Gilbert-Peierls DFS vs column-sweep scan ---
    let gp = gp_kernels(micro_m, micro_reps);
    println!(
        "\n{:<10} {:>12} {:>12} {:>10} {:>10} {:>10}",
        "gp kernel", "dfs", "scan", "dfs_work", "scan_work", "out_nnz"
    );
    for g in &gp {
        println!(
            "{:<10} {:>10.0}ns {:>10.0}ns {:>10} {:>10} {:>10}",
            g.kernel, g.dfs_ns, g.scan_ns, g.dfs_work, g.scan_work, g.result_nnz
        );
    }

    // --- warm sweep cells (widest grid instance) ---
    let cells = [
        sweep_cell(
            "dense_tableau/full",
            Backend::DenseTableau,
            Factorization::ProductFormEta,
            Pricing::Dantzig,
            &base,
            sweep_points,
        ),
        sweep_cell(
            "revised/full",
            Backend::RevisedSimplex,
            Factorization::ProductFormEta,
            Pricing::Dantzig,
            &base,
            sweep_points,
        ),
        sweep_cell(
            "revised/partial",
            Backend::RevisedSimplex,
            Factorization::ProductFormEta,
            Pricing::Partial,
            &base,
            sweep_points,
        ),
        sweep_cell(
            "revised/ft/partial",
            Backend::RevisedSimplex,
            Factorization::ForrestTomlin,
            Pricing::Partial,
            &base,
            sweep_points,
        ),
        sweep_cell(
            "revised/bg/partial",
            Backend::RevisedSimplex,
            Factorization::BartelsGolub,
            Pricing::Partial,
            &base,
            sweep_points,
        ),
    ];
    println!(
        "\n{:<20} {:>10} {:>10} {:>10} {:>10} {:>8} {:>9} {:>12}",
        "cell", "cold_ms", "cold_iter", "sweep_ms", "sweep_iter", "hits", "refresh", "avg_ftr_nnz"
    );
    for c in &cells {
        println!(
            "{:<20} {:>10.2} {:>10} {:>10.2} {:>10} {:>8} {:>9} {:>12.1}",
            c.label,
            c.cold_ms,
            c.cold_iterations,
            c.sweep_ms,
            c.sweep_iterations,
            c.candidate_hits,
            c.candidate_refreshes,
            c.avg_ftran_nnz
        );
    }

    let dense_cell = &cells[0];
    let partial_cell = &cells[2];
    let ft_cell = &cells[3];
    let bg_cell = &cells[4];
    let speedup = dense_cell.sweep_ms / partial_cell.sweep_ms.max(1e-9);
    let note = format!(
        "warm sweep (nfe n={n} m={m}, {sweep_points} points): sparse kernels + partial \
         pricing {:.2}ms vs dense baseline cell {:.2}ms ({speedup:.1}x)",
        partial_cell.sweep_ms, dense_cell.sweep_ms
    );
    println!("   note: {note}");
    let bg_note = format!(
        "update-file race (same sweep): forrest_tomlin {:.2}ms vs bartels_golub {:.2}ms",
        ft_cell.sweep_ms, bg_cell.sweep_ms
    );
    println!("   note: {bg_note}");

    // --- JSON artifact ---
    let micro_json: Vec<Json> = micro
        .iter()
        .map(|mc| {
            Json::Object(vec![
                ("strategy".into(), Json::Str(mc.strategy.as_str().into())),
                ("dense_is_adapter".into(), Json::Bool(mc.dense_is_adapter)),
                ("m".into(), Json::Num(micro_m as f64)),
                ("ftran_dense_ns".into(), Json::Num(mc.ftran_dense_ns)),
                ("ftran_sparse_ns".into(), Json::Num(mc.ftran_sparse_ns)),
                ("btran_dense_ns".into(), Json::Num(mc.btran_dense_ns)),
                ("btran_sparse_ns".into(), Json::Num(mc.btran_sparse_ns)),
                ("storage_nnz".into(), Json::Num(mc.storage_nnz as f64)),
                (
                    "dense_equivalent_entries".into(),
                    Json::Num(mc.dense_equivalent as f64),
                ),
            ])
        })
        .collect();
    let gp_json: Vec<Json> = gp
        .iter()
        .map(|g| {
            Json::Object(vec![
                ("kernel".into(), Json::Str(g.kernel.into())),
                ("m".into(), Json::Num(micro_m as f64)),
                ("dfs_ns".into(), Json::Num(g.dfs_ns)),
                ("scan_ns".into(), Json::Num(g.scan_ns)),
                ("dfs_work".into(), Json::Num(g.dfs_work as f64)),
                ("scan_work".into(), Json::Num(g.scan_work as f64)),
                ("result_nnz".into(), Json::Num(g.result_nnz as f64)),
            ])
        })
        .collect();
    let cell_json: Vec<Json> = cells
        .iter()
        .map(|c| {
            Json::Object(vec![
                ("cell".into(), Json::Str(c.label.into())),
                ("backend".into(), Json::Str(c.backend.as_str().into())),
                ("factorization".into(), Json::Str(c.factorization.as_str().into())),
                ("pricing".into(), Json::Str(c.pricing.as_str().into())),
                ("cold_ms".into(), Json::Num(c.cold_ms)),
                ("cold_iterations".into(), Json::Num(c.cold_iterations as f64)),
                ("sweep_ms".into(), Json::Num(c.sweep_ms)),
                ("sweep_iterations".into(), Json::Num(c.sweep_iterations as f64)),
                ("candidate_hits".into(), Json::Num(c.candidate_hits as f64)),
                (
                    "candidate_refreshes".into(),
                    Json::Num(c.candidate_refreshes as f64),
                ),
                ("avg_ftran_nnz".into(), Json::Num(c.avg_ftran_nnz)),
            ])
        })
        .collect();
    let doc = Json::Object(vec![
        ("group".into(), Json::Str("hypersparse".into())),
        (
            "instance".into(),
            Json::Str(format!(
                "nfe n={n} m={m}, {sweep_points}-point warm sweep; micro kernels m={micro_m}"
            )),
        ),
        ("micro_kernels".into(), Json::Array(micro_json)),
        ("gp_kernels".into(), Json::Array(gp_json)),
        ("sweep_cells".into(), Json::Array(cell_json)),
        ("notes".into(), Json::Array(vec![Json::Str(note), Json::Str(bg_note)])),
    ]);
    if let Ok(dir) = std::env::var("DLT_BENCH_JSON_DIR") {
        std::fs::create_dir_all(&dir).expect("create bench json dir");
        let path = std::path::Path::new(&dir).join("BENCH_hypersparse.json");
        std::fs::write(&path, doc.to_string_pretty()).expect("write BENCH_hypersparse.json");
        println!("   wrote {}", path.display());
    }

    // --- regression gates (CI) ---
    if assert_gates {
        for mc in &micro {
            // Only product_form_eta keeps an independent dense kernel;
            // Forrest-Tomlin's dense entry points are adapters over the
            // sparse path, so comparing them would be a tautology.
            if !mc.dense_is_adapter {
                assert!(
                    mc.ftran_sparse_ns <= mc.ftran_dense_ns * 1.10,
                    "{}: sparse ftran ({:.0}ns) regressed behind the dense kernel ({:.0}ns)",
                    mc.strategy.as_str(),
                    mc.ftran_sparse_ns,
                    mc.ftran_dense_ns
                );
            }
            assert!(
                mc.storage_nnz * 4 < mc.dense_equivalent,
                "{}: factor storage {} entries is no longer sparse (dense pair {})",
                mc.strategy.as_str(),
                mc.storage_nnz,
                mc.dense_equivalent
            );
        }
        // The Gilbert-Peierls gate is on the deterministic work
        // counter, not wall time: the symbolic DFS must visit strictly
        // fewer nodes than the full column sweep on the same solve.
        for g in &gp {
            assert!(
                g.dfs_work < g.scan_work,
                "gp {}: DFS visited {} nodes, no better than the {}-node column sweep",
                g.kernel,
                g.dfs_work,
                g.scan_work
            );
            assert!(g.result_nnz > 0, "gp {}: solve produced an empty result", g.kernel);
        }
        // 1.5x slack: on DLT_BENCH_FAST instances the totals are
        // sub-millisecond, where runner jitter is a real fraction.
        assert!(
            partial_cell.sweep_ms <= dense_cell.sweep_ms * 1.5,
            "sparse warm-sweep path ({:.2}ms) slower than the dense baseline cell ({:.2}ms)",
            partial_cell.sweep_ms,
            dense_cell.sweep_ms
        );
        // The update-file race is informational, but both contenders
        // must have actually solved the sweep to the same iteration
        // count ballpark (a wildly divergent count means a broken
        // update chain, not a slow one).
        assert!(
            ft_cell.sweep_iterations > 0 && bg_cell.sweep_iterations > 0,
            "update-file race cells did not pivot"
        );
        println!("   regression gates passed");
    }
}
