//! Bench: PJRT artifact execution — workload units and PDHG blocks.
//!
//! §Perf harness for Layer 1/2 as seen from the rust hot path
//! (artifact execution latency; compile time is amortized and cached).

use dlt::benchkit::{Bencher, Reporter};
use dlt::runtime::{Runtime, WorkloadExecutable};

fn main() {
    let b = Bencher::from_env();
    let mut rep = Reporter::new("runtime (PJRT artifact execution)");

    if !Runtime::artifacts_available() {
        rep.note("artifacts/ not built (run `make artifacts`); nothing to measure");
        rep.finish();
        return;
    }

    let mut w = WorkloadExecutable::open("artifacts", 42).expect("open workload");
    rep.report("workload_unit_128x128", b.bench_val(|| w.run_unit().unwrap()));

    // One PDHG block on the smallest variant.
    let mut rt = Runtime::open_default().expect("runtime");
    let var = rt.manifest().pdhg.first().expect("pdhg variant").clone();
    let mut p = dlt::lp::LpProblem::new(8);
    p.set_objective(&[1.0; 8]);
    p.add_constraint(&(0..8).map(|v| (v, 1.0)).collect::<Vec<_>>(), dlt::lp::Cmp::Eq, 4.0);
    let pad = dlt::pdhg::PaddedLp::build(&p, var.nv, var.nc);
    let mut exec =
        dlt::runtime::PdhgExecutable::for_shape(&mut rt, 8, 1).expect("bind pdhg");
    let x = vec![0.0; pad.nv];
    let y = vec![0.0; pad.nc];
    rep.report(
        &format!("pdhg_block_{}x{}_{}steps", var.nv, var.nc, var.steps),
        b.bench_val(|| {
            exec.run_block(&pad.a, &pad.at, &pad.b, &pad.c, &pad.eq_mask, &x, &y, 0.1, 0.1)
                .unwrap()
        }),
    );
    rep.finish();
}
