//! Bench: open-loop load harness for the `dlt serve` TCP tier.
//!
//! Drives a live server — in-process by default, or an external one
//! via `DLT_SERVE_ADDR=host:port` (the CI smoke job boots
//! `dlt serve` on loopback and points this harness at it) — with a
//! fixed-seed mixed-family workload over persistent connections:
//!
//! - **calibrate** — every connection blasts requests as fast as it
//!   can; accepted throughput estimates server capacity;
//! - **sustained** — open-loop Poisson arrivals at ~0.6x capacity,
//!   reporting sustained req/s, p50/p99/p999 latency and the
//!   warm-shard hit rate under client-keyed load;
//! - **overload** — arrivals at 2x capacity; the bounded admission
//!   queues must shed (fast-reject with `retry_after_ms`) while the
//!   accepted requests keep a bounded p99;
//! - **eviction probe** — 64 distinct clients against a small warm
//!   budget, forcing LRU session evictions visible in the per-response
//!   `diagnostics.serve` block.
//!
//! Open loop means senders never wait for responses: arrival times
//! are drawn up front from a seeded PCG stream, so offered load is
//! independent of server behavior (the difference between measuring
//! latency and measuring the client's politeness). With
//! `DLT_BENCH_JSON_DIR=dir` the results land in `dir/BENCH_serve.json`
//! (gated by `scripts/check_bench_schema.py`); `DLT_BENCH_FAST=1`
//! shrinks the request counts for CI; `DLT_BENCH_ASSERT=1` turns the
//! in-harness regression gates on.

use dlt::api::{Family, SolveRequest};
use dlt::config::json::Json;
use dlt::dlt::concurrent::Mode;
use dlt::lp::{Factorization, Pricing};
use dlt::model::SystemSpec;
use dlt::serve::{ServeOptions, Server};
use dlt::util::{Pcg32, Rng};
use std::collections::HashMap;
use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::thread;
use std::time::{Duration, Instant};

const PROCS: usize = 4;

fn spec(job: f64) -> SystemSpec {
    SystemSpec::builder()
        .source(0.2, 10.0)
        .source(0.4, 50.0)
        .processors(&[2.0, 2.5, 3.0, 3.5])
        .job(job)
        .build()
        .expect("bench spec")
}

/// One wire line: a client-keyed request cycling through all four
/// families with factorization/pricing overrides on a rotating subset.
fn request_line(client: &str, k: usize) -> String {
    let family = match k % 4 {
        0 => Family::Frontend,
        1 => Family::NoFrontend,
        2 => Family::Concurrent,
        _ => Family::MultiJob,
    };
    let mut req = SolveRequest::new(family, spec(80.0 + 20.0 * (k % 5) as f64));
    req.id = Some(format!("{client}-{k}"));
    match k % 5 {
        1 => req.options.factorization = Some(Factorization::ForrestTomlin),
        2 => req.options.pricing = Some(Pricing::Partial),
        3 => {
            req.options.factorization = Some(Factorization::BartelsGolub);
            req.options.pricing = Some(Pricing::Devex);
        }
        _ => {}
    }
    if family == Family::Concurrent {
        req.options.mode = Some(if k % 8 < 4 { Mode::Proportional } else { Mode::Staggered });
    }
    if family == Family::MultiJob {
        req.options.proc_ready = Some(vec![0.25; PROCS]);
    }
    let mut doc = req.to_json();
    if let Json::Object(kv) = &mut doc {
        kv.insert(0, ("client".to_string(), Json::Str(client.to_string())));
    }
    doc.to_string_compact()
}

/// Per-response `diagnostics.serve` block, when present.
struct ServeDiag {
    shard: usize,
    shard_hit: bool,
    evictions: u64,
    resident: usize,
}

enum Kind {
    Ok,
    Shed,
    Error,
}

struct Event {
    seq: usize,
    t: Instant,
    kind: Kind,
    serve: Option<ServeDiag>,
}

fn parse_event(line: &str, t: Instant) -> Option<Event> {
    let doc = Json::parse(line).ok()?;
    let seq = doc.get("seq")?.as_usize().ok()?;
    if let Some(err) = doc.get("error") {
        let overloaded = err.get("kind").and_then(|k| k.as_str().ok()) == Some("overloaded");
        let kind = if overloaded { Kind::Shed } else { Kind::Error };
        return Some(Event { seq, t, kind, serve: None });
    }
    let serve = doc.get("diagnostics").and_then(|d| d.get("serve")).map(|s| ServeDiag {
        shard: s.get("shard").and_then(|x| x.as_usize().ok()).unwrap_or(0),
        shard_hit: s.get("shard_hit").and_then(|x| x.as_bool().ok()).unwrap_or(false),
        evictions: s.get("evictions").and_then(|x| x.as_f64().ok()).unwrap_or(0.0) as u64,
        resident: s.get("resident").and_then(|x| x.as_usize().ok()).unwrap_or(0),
    });
    Some(Event { seq, t, kind: Kind::Ok, serve })
}

/// Aggregated outcome of one load phase.
struct PhaseOut {
    offered: usize,
    accepted: usize,
    shed: usize,
    errors: usize,
    /// Responses never received before the read timeout (should be 0:
    /// every admitted *or shed* request gets exactly one line back).
    lost: usize,
    wall_s: f64,
    /// Sorted solve latencies (accepted requests only), milliseconds.
    lat_ms: Vec<f64>,
    shard_hits: usize,
    shard_total: usize,
    /// Per-shard (min, max) cumulative eviction counters observed.
    evictions: HashMap<usize, (u64, u64)>,
    max_resident: usize,
}

impl PhaseOut {
    fn shed_rate(&self) -> f64 {
        self.shed as f64 / (self.offered.max(1)) as f64
    }

    fn hit_rate(&self) -> f64 {
        self.shard_hits as f64 / (self.shard_total.max(1)) as f64
    }

    fn req_s(&self) -> f64 {
        self.accepted as f64 / self.wall_s.max(1e-9)
    }

    fn pctl(&self, q: f64) -> f64 {
        if self.lat_ms.is_empty() {
            return 0.0;
        }
        dlt::util::stats::percentile_sorted(&self.lat_ms, q)
    }

    /// Evictions that happened *during* this phase: per-shard growth
    /// of the cumulative counter between the first and last response
    /// observed from that shard.
    fn evictions_seen(&self) -> u64 {
        self.evictions.values().map(|&(lo, hi)| hi - lo).sum()
    }
}

/// Run one open-loop phase: `conns` persistent connections, each
/// sending `per_conn` requests with exponential inter-arrivals at
/// `rate_per_conn` req/s (`f64::INFINITY` = blast). Client ids cycle
/// through `clients`, offset per connection.
fn run_phase(
    addr: &str,
    conns: usize,
    per_conn: usize,
    rate_per_conn: f64,
    clients: &[String],
    seed: u64,
    read_timeout: Duration,
) -> PhaseOut {
    let t0 = Instant::now();
    let mut pairs = Vec::new();
    for c in 0..conns {
        let stream = TcpStream::connect(addr).expect("connect to serve tier");
        stream.set_nodelay(true).expect("nodelay");
        let reader_stream = stream.try_clone().expect("clone stream");
        reader_stream.set_read_timeout(Some(read_timeout)).expect("read timeout");

        // Pre-draw arrival offsets and pre-serialize lines so neither
        // costs anything inside the send loop.
        let mut rng = Pcg32::with_stream(seed, c as u64);
        let mut lines = Vec::with_capacity(per_conn);
        let mut arrivals = Vec::with_capacity(per_conn);
        let mut at = 0.0f64;
        for i in 0..per_conn {
            let client = &clients[(i + c) % clients.len()];
            lines.push(request_line(client, i + c));
            if rate_per_conn.is_finite() {
                at += -(1.0 - rng.f64()).ln() / rate_per_conn;
            }
            arrivals.push(at);
        }

        let sender = thread::spawn(move || {
            let mut stream = stream;
            let start = Instant::now();
            let mut sent = Vec::with_capacity(lines.len());
            for (line, &at) in lines.iter().zip(&arrivals) {
                let target = start + Duration::from_secs_f64(at);
                let now = Instant::now();
                if target > now {
                    thread::sleep(target - now);
                }
                sent.push(Instant::now());
                stream.write_all(line.as_bytes()).expect("send request");
                stream.write_all(b"\n").expect("send newline");
            }
            sent
        });
        let reader = thread::spawn(move || {
            let mut r = BufReader::new(reader_stream);
            let mut events = Vec::with_capacity(per_conn);
            let mut line = String::new();
            while events.len() < per_conn {
                line.clear();
                match r.read_line(&mut line) {
                    Ok(0) | Err(_) => break, // EOF or timed out
                    Ok(_) => {
                        if let Some(ev) = parse_event(line.trim_end(), Instant::now()) {
                            events.push(ev);
                        }
                    }
                }
            }
            events
        });
        pairs.push((sender, reader));
    }

    let mut out = PhaseOut {
        offered: conns * per_conn,
        accepted: 0,
        shed: 0,
        errors: 0,
        lost: 0,
        wall_s: 0.0,
        lat_ms: Vec::new(),
        shard_hits: 0,
        shard_total: 0,
        evictions: HashMap::new(),
        max_resident: 0,
    };
    for (sender, reader) in pairs {
        let sent = sender.join().expect("sender thread");
        let events = reader.join().expect("reader thread");
        out.lost += per_conn - events.len();
        for ev in events {
            match ev.kind {
                Kind::Shed => out.shed += 1,
                Kind::Error => out.errors += 1,
                Kind::Ok => {
                    out.accepted += 1;
                    if ev.seq < sent.len() {
                        let dt = ev.t.duration_since(sent[ev.seq]);
                        out.lat_ms.push(dt.as_secs_f64() * 1e3);
                    }
                    if let Some(s) = ev.serve {
                        out.shard_total += 1;
                        if s.shard_hit {
                            out.shard_hits += 1;
                        }
                        let span = out.evictions.entry(s.shard).or_insert((s.evictions, 0));
                        span.0 = span.0.min(s.evictions);
                        span.1 = span.1.max(s.evictions);
                        out.max_resident = out.max_resident.max(s.resident);
                    }
                }
            }
        }
    }
    out.wall_s = t0.elapsed().as_secs_f64();
    out.lat_ms.sort_by(|a, b| a.partial_cmp(b).expect("finite latency"));
    out
}

fn phase_json(name: &str, p: &PhaseOut, extra: Vec<(String, Json)>) -> (String, Json) {
    let mut kv = vec![
        ("offered".to_string(), Json::Num(p.offered as f64)),
        ("accepted".to_string(), Json::Num(p.accepted as f64)),
        ("shed".to_string(), Json::Num(p.shed as f64)),
        ("errors".to_string(), Json::Num(p.errors as f64)),
        ("lost".to_string(), Json::Num(p.lost as f64)),
        ("wall_s".to_string(), Json::Num(p.wall_s)),
        ("req_s".to_string(), Json::Num(p.req_s())),
        ("shed_rate".to_string(), Json::Num(p.shed_rate())),
        ("p50_ms".to_string(), Json::Num(p.pctl(0.50))),
        ("p99_ms".to_string(), Json::Num(p.pctl(0.99))),
        ("p999_ms".to_string(), Json::Num(p.pctl(0.999))),
        ("warm_shard_hit_rate".to_string(), Json::Num(p.hit_rate())),
        ("evictions_seen".to_string(), Json::Num(p.evictions_seen() as f64)),
        ("max_resident".to_string(), Json::Num(p.max_resident as f64)),
    ];
    kv.extend(extra);
    (name.to_string(), Json::Object(kv))
}

fn print_phase(name: &str, p: &PhaseOut) {
    println!(
        "{name:<12} offered {:>5}  accepted {:>5}  shed {:>4} ({:>5.1}%)  \
         {:>8.0} req/s  p50 {:>7.2}ms  p99 {:>7.2}ms  p999 {:>7.2}ms  \
         hit {:>5.1}%  evicted {:>3}  lost {}",
        p.offered,
        p.accepted,
        p.shed,
        p.shed_rate() * 100.0,
        p.req_s(),
        p.pctl(0.50),
        p.pctl(0.99),
        p.pctl(0.999),
        p.hit_rate() * 100.0,
        p.evictions_seen(),
        p.lost
    );
}

fn main() {
    let fast = std::env::var("DLT_BENCH_FAST").is_ok();
    let assert_gates = std::env::var("DLT_BENCH_ASSERT").is_ok();
    let (conns, cal_n, sus_n, over_n) = if fast { (2, 40, 120, 400) } else { (4, 100, 400, 600) };
    let read_timeout = Duration::from_secs(if fast { 20 } else { 60 });
    let seed = 0x5EEDu64;

    // External server via DLT_SERVE_ADDR (the CI smoke job), or an
    // in-process one with the same small warm budget the CI job uses
    // (48 KiB over 8 shards) so the eviction probe bites either way.
    let external = std::env::var("DLT_SERVE_ADDR").ok();
    let (addr, server) = match &external {
        Some(a) => (a.clone(), None),
        None => {
            let opts = ServeOptions {
                addr: "127.0.0.1:0".to_string(),
                workers: 2,
                shards: 8,
                queue_depth: 32,
                warm_budget_bytes: 48 * 1024,
                ..ServeOptions::default()
            };
            let srv = Server::start(opts).expect("start in-process server");
            (srv.local_addr().to_string(), Some(srv))
        }
    };
    println!(
        "== bench group: serve (open-loop load vs {} at {addr}) ==",
        if external.is_some() { "external server" } else { "in-process server" }
    );

    // Small keyed tenant set: spreads over the shards but stays well
    // inside the warm budget, so sustained load measures *hits*.
    let tenants: Vec<String> = (0..8).map(|i| format!("tenant-{i}")).collect();
    // One probe client per eviction slot: 64 sessions cannot all fit.
    let probes: Vec<String> = (0..64).map(|i| format!("probe-{i}")).collect();

    let calibrate = run_phase(&addr, conns, cal_n, f64::INFINITY, &tenants, seed, read_timeout);
    print_phase("calibrate", &calibrate);
    let capacity = calibrate.req_s().max(1.0);

    let sus_rate = 0.6 * capacity / conns as f64;
    let sustained = run_phase(&addr, conns, sus_n, sus_rate, &tenants, seed + 1, read_timeout);
    print_phase("sustained", &sustained);

    let over_rate = 2.0 * capacity / conns as f64;
    let overload = run_phase(&addr, conns, over_n, over_rate, &tenants, seed + 2, read_timeout);
    print_phase("overload", &overload);

    // Two passes over the probe clients: the first pass floods the
    // budget, the second demonstrates that evicted clients come back
    // cold while the hottest survivors stay warm.
    let probe_n = 2 * probes.len();
    let probe = run_phase(&addr, 1, probe_n, f64::INFINITY, &probes, seed + 3, read_timeout);
    print_phase("eviction", &probe);

    let note = format!(
        "capacity ~{capacity:.0} req/s; sustained at 0.6x: {:.0} req/s, p99 {:.2}ms, \
         warm-shard hit rate {:.0}%; at 2.0x: shed {:.0}% with accepted p99 {:.2}ms; \
         64-client probe evicted {} warm sessions",
        sustained.req_s(),
        sustained.pctl(0.99),
        sustained.hit_rate() * 100.0,
        overload.shed_rate() * 100.0,
        overload.pctl(0.99),
        probe.evictions_seen()
    );
    println!("   note: {note}");

    if let Some(srv) = server {
        let stats = srv.shutdown();
        println!(
            "   server counters: {} conns, {} requests, {} responses, {} shed, \
             {} malformed, {} evictions, {}/{} shard hits/misses",
            stats.connections,
            stats.requests,
            stats.responses,
            stats.shed,
            stats.malformed,
            stats.evictions,
            stats.shard_hits,
            stats.shard_misses
        );
    }

    // --- JSON artifact ---
    let mode = if external.is_some() { "external" } else { "in_process" };
    let config = Json::Object(vec![
        ("mode".to_string(), Json::Str(mode.to_string())),
        ("addr".to_string(), Json::Str(addr.clone())),
        ("conns".to_string(), Json::Num(conns as f64)),
        ("tenants".to_string(), Json::Num(tenants.len() as f64)),
        ("probe_clients".to_string(), Json::Num(probes.len() as f64)),
        ("seed".to_string(), Json::Num(seed as f64)),
        ("capacity_rps".to_string(), Json::Num(capacity)),
    ]);
    let doc = Json::Object(vec![
        ("group".to_string(), Json::Str("serve".to_string())),
        ("config".to_string(), config),
        phase_json("calibrate", &calibrate, vec![]),
        phase_json(
            "sustained",
            &sustained,
            vec![("target_rps".to_string(), Json::Num(sus_rate * conns as f64))],
        ),
        phase_json(
            "overload",
            &overload,
            vec![
                ("target_rps".to_string(), Json::Num(over_rate * conns as f64)),
                ("accepted_p99_ms".to_string(), Json::Num(overload.pctl(0.99))),
            ],
        ),
        phase_json("eviction_probe", &probe, vec![]),
        ("notes".to_string(), Json::Array(vec![Json::Str(note)])),
    ]);
    if let Ok(dir) = std::env::var("DLT_BENCH_JSON_DIR") {
        std::fs::create_dir_all(&dir).expect("create bench json dir");
        let path = std::path::Path::new(&dir).join("BENCH_serve.json");
        std::fs::write(&path, doc.to_string_pretty()).expect("write BENCH_serve.json");
        println!("   wrote {}", path.display());
    }

    // --- regression gates (CI) ---
    if assert_gates {
        assert!(sustained.accepted > 0, "sustained phase solved nothing");
        assert!(
            sustained.pctl(0.50) > 0.0 && sustained.pctl(0.50) <= sustained.pctl(0.99),
            "latency percentiles are not ordered"
        );
        assert!(
            sustained.hit_rate() > 0.0,
            "client-keyed load never hit a warm shard (hit rate 0)"
        );
        assert!(sustained.shed_rate() < 1.0, "sustained load was entirely shed");
        assert!(
            overload.shed > 0,
            "2x overload produced no shed responses — admission control is not bounding queues"
        );
        assert!(overload.accepted > 0, "2x overload starved every request");
        assert!(probe.evictions_seen() > 0, "64-client probe forced no LRU evictions");
        assert_eq!(
            sustained.lost + overload.lost + probe.lost,
            0,
            "some requests never received a response line"
        );
        println!("   regression gates passed");
    }
}
