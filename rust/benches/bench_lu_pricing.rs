//! Bench: the factorization × pricing strategy grid on the largest
//! N × M instances — the measurement behind the Forrest–Tomlin and
//! devex/steepest-edge ROADMAP bullets.
//!
//! Two workloads per `(factorization, pricing)` cell:
//!
//! - **cold long-pivot solve** — one cold NFE solve on the largest
//!   spec (hundreds of pivots, well past the 48-pivot eta cadence):
//!   the case LU updating exists for. The JSON records iterations,
//!   full refactorizations and wall time, so the artifact shows
//!   Forrest–Tomlin refactorizing less than the product-form eta file
//!   on exactly this instance.
//! - **warm job sweep** — a warm-started job-size sweep through one
//!   `dlt::api` session (the production shape: perturbed re-solves
//!   with dual-simplex repairs), summed over the grid.
//!
//! With `DLT_BENCH_JSON_DIR=dir` the results land in
//! `dir/BENCH_lu_pricing.json`; `DLT_BENCH_FAST=1` shrinks the
//! instance for CI smoke runs.

use dlt::api::{Family, SolveRequest, Solver};
use dlt::config::json::Json;
use dlt::lp::{Factorization, Pricing, SimplexOptions};
use dlt::model::SystemSpec;
use std::time::Instant;

fn spec(n: usize, m: usize) -> SystemSpec {
    let mut b = SystemSpec::builder();
    for i in 0..n {
        b = b.source(0.5 + 0.01 * i as f64, i as f64 * 0.5);
    }
    let a: Vec<f64> = (0..m).map(|k| 1.1 + 0.1 * k as f64).collect();
    b.processors(&a).job(100.0).build().unwrap()
}

struct Cell {
    factorization: Factorization,
    pricing: Pricing,
    cold_iterations: usize,
    cold_refactorizations: usize,
    cold_update_len: usize,
    cold_wall_ms: f64,
    sweep_iterations: usize,
    sweep_refactorizations: usize,
    sweep_wall_ms: f64,
}

fn main() {
    let fast = std::env::var("DLT_BENCH_FAST").is_ok();
    let (n, m) = if fast { (3usize, 10usize) } else { (3, 24) };
    let sweep_points = if fast { 8 } else { 24 };
    let base = spec(n, m);

    println!("== bench group: lu_pricing (factorization x pricing, NFE n={n} m={m}) ==");
    println!(
        "{:<18} {:<14} {:>10} {:>8} {:>8} {:>10} {:>10} {:>8} {:>10}",
        "factorization",
        "pricing",
        "cold_iter",
        "refact",
        "upd_len",
        "cold_ms",
        "sweep_iter",
        "refact",
        "sweep_ms"
    );

    let mut cells: Vec<Cell> = Vec::new();
    for factorization in [Factorization::ProductFormEta, Factorization::ForrestTomlin] {
        for pricing in [Pricing::Dantzig, Pricing::Devex, Pricing::SteepestEdge] {
            let simplex =
                SimplexOptions { factorization, pricing, ..SimplexOptions::default() };

            // Cold long-pivot instance.
            let mut cold_session =
                Solver::new().warm_start(false).simplex(simplex.clone()).build();
            let t0 = Instant::now();
            let cold = cold_session
                .solve(&SolveRequest::new(Family::NoFrontend, base.clone()))
                .expect("cold long-pivot solve");
            let cold_wall_ms = t0.elapsed().as_secs_f64() * 1e3;

            // Warm job sweep through one session.
            let mut session = Solver::new().simplex(simplex).build();
            let t0 = Instant::now();
            let mut sweep_iterations = 0usize;
            let mut sweep_refactorizations = 0usize;
            for k in 0..sweep_points {
                let sub = base.with_job(100.0 + 10.0 * k as f64);
                let resp = session
                    .solve(&SolveRequest::new(Family::NoFrontend, sub))
                    .expect("sweep solve");
                sweep_iterations += resp.diagnostics.iterations;
                sweep_refactorizations += resp.diagnostics.refactorizations;
            }
            let sweep_wall_ms = t0.elapsed().as_secs_f64() * 1e3;

            println!(
                "{:<18} {:<14} {:>10} {:>8} {:>8} {:>10.2} {:>10} {:>8} {:>10.2}",
                factorization.as_str(),
                pricing.as_str(),
                cold.diagnostics.iterations,
                cold.diagnostics.refactorizations,
                cold.diagnostics.update_len,
                cold_wall_ms,
                sweep_iterations,
                sweep_refactorizations,
                sweep_wall_ms
            );
            cells.push(Cell {
                factorization,
                pricing,
                cold_iterations: cold.diagnostics.iterations,
                cold_refactorizations: cold.diagnostics.refactorizations,
                cold_update_len: cold.diagnostics.update_len,
                cold_wall_ms,
                sweep_iterations,
                sweep_refactorizations,
                sweep_wall_ms,
            });
        }
    }

    // Headline note: the tentpole's refactorization claim, measured.
    let cold_refacts = |f: Factorization| -> usize {
        cells
            .iter()
            .filter(|c| c.factorization == f && c.pricing == Pricing::Dantzig)
            .map(|c| c.cold_refactorizations)
            .sum()
    };
    let pfe = cold_refacts(Factorization::ProductFormEta);
    let ft = cold_refacts(Factorization::ForrestTomlin);
    let note = format!(
        "long-pivot cold solve (dantzig): forrest_tomlin refactorized {ft}x vs \
         product_form_eta {pfe}x"
    );
    println!("   note: {note}");

    let entries: Vec<Json> = cells
        .iter()
        .map(|c| {
            Json::Object(vec![
                ("factorization".into(), Json::Str(c.factorization.as_str().into())),
                ("pricing".into(), Json::Str(c.pricing.as_str().into())),
                ("cold_iterations".into(), Json::Num(c.cold_iterations as f64)),
                (
                    "cold_refactorizations".into(),
                    Json::Num(c.cold_refactorizations as f64),
                ),
                ("cold_update_len".into(), Json::Num(c.cold_update_len as f64)),
                ("cold_wall_ms".into(), Json::Num(c.cold_wall_ms)),
                ("sweep_iterations".into(), Json::Num(c.sweep_iterations as f64)),
                (
                    "sweep_refactorizations".into(),
                    Json::Num(c.sweep_refactorizations as f64),
                ),
                ("sweep_wall_ms".into(), Json::Num(c.sweep_wall_ms)),
            ])
        })
        .collect();
    let doc = Json::Object(vec![
        ("group".into(), Json::Str("lu_pricing".into())),
        ("instance".into(), Json::Str(format!("nfe n={n} m={m}, {sweep_points}-point sweep"))),
        ("entries".into(), Json::Array(entries)),
        ("notes".into(), Json::Array(vec![Json::Str(note)])),
    ]);
    if let Ok(dir) = std::env::var("DLT_BENCH_JSON_DIR") {
        std::fs::create_dir_all(&dir).expect("create bench json dir");
        let path = std::path::Path::new(&dir).join("BENCH_lu_pricing.json");
        std::fs::write(&path, doc.to_string_pretty()).expect("write BENCH_lu_pricing.json");
        println!("   wrote {}", path.display());
    }
}
