//! Domain scenario (paper §1.2.3): sizing a wireless-sensor-network
//! data-fusion deployment.
//!
//! ```bash
//! cargo run --release --example sensor_network
//! ```
//!
//! A sensing campaign produces a divisible measurement archive that two
//! gateway stations (the *sources*, released as their uplinks come
//! online) distribute to a heterogeneous pool of fusion nodes. The
//! operator pays per busy-hour and wants answers to the paper's three
//! questions: how fast can we finish, what does it cost, and where is
//! the knee? Includes a robustness check: how much does the optimized
//! schedule degrade when real link speeds jitter ±10 %?

use dlt::cost::{advise, Advice, Budgets, TradeoffTable};
use dlt::dlt::schedule::TimingModel;
use dlt::dlt::frontend::FeOptions;
use dlt::dlt::no_frontend::NfeOptions;
use dlt::pipeline;
use dlt::model::SystemSpec;
use dlt::sim::{simulate, SimOptions};
use dlt::util::stats::Summary;

fn main() -> anyhow::Result<()> {
    dlt::util::logger::init();

    // Two gateways; 12 fusion nodes from fast/expensive to slow/cheap.
    let ac: Vec<(f64, f64)> = (0..12)
        .map(|k| (0.8 + 0.25 * k as f64, 24.0 - 1.5 * k as f64))
        .collect();
    let spec = SystemSpec::builder()
        .source(0.10, 0.0) // fiber gateway, ready at t=0
        .source(0.15, 2.0) // LTE gateway, online at t=2
        .priced_processors(&ac)
        .job(240.0) // GB of sensor data
        .build()?;

    println!("== full fleet, both timing models ==");
    let fe = pipeline::solve(&FeOptions::default(), &spec)?;
    let nfe = pipeline::solve(&NfeOptions::default(), &spec)?;
    println!("T_f with front-ends:    {:.3} h", fe.makespan);
    println!("T_f without front-ends: {:.3} h", nfe.makespan);
    println!(
        "front-end hardware buys {:.1}% faster completion\n",
        (1.0 - fe.makespan / nfe.makespan) * 100.0
    );

    println!("== fleet sizing (paper §6) ==");
    let sweep = TradeoffTable::sweep(&spec)?;
    for p in &sweep.points {
        println!("  {:>2} nodes: T_f {:>8.3} h  cost ${:>8.2}", p.m, p.tf, p.cost);
    }
    for (label, budgets) in [
        ("deadline 40 h", Budgets { cost: None, time: Some(40.0), gradient_threshold: 0.0 }),
        ("budget $6400", Budgets { cost: Some(6400.0), time: None, gradient_threshold: 0.06 }),
        (
            "deadline 44 h AND budget $6640",
            Budgets { cost: Some(6640.0), time: Some(44.0), gradient_threshold: 0.06 },
        ),
        (
            "deadline 40 h AND budget $6400 (disjoint)",
            Budgets { cost: Some(6400.0), time: Some(40.0), gradient_threshold: 0.06 },
        ),
    ] {
        match advise(&sweep, &budgets) {
            Advice::Use { m, tf, cost } => {
                println!("{label}: deploy {m} nodes (T_f {tf:.2} h, ${cost:.2})")
            }
            Advice::Range { lo, hi, recommended } => {
                println!("{label}: {lo}..{hi} nodes all work; deploy {recommended}")
            }
            Advice::Infeasible { .. } => println!("{label}: infeasible — relax a budget"),
        }
    }

    println!("\n== robustness: ±10% link jitter on the optimized schedule ==");
    let mut makespans = Vec::new();
    for seed in 0..200u64 {
        let res = simulate(
            &spec,
            &nfe.beta,
            &SimOptions {
                model: TimingModel::NoFrontEnd,
                link_jitter: 0.10,
                compute_jitter: 0.0,
                seed,
                trace: false,
            },
        );
        makespans.push(res.makespan);
    }
    let s = Summary::of(&makespans);
    println!("nominal T_f {:.3} h; under jitter: median {:.3}, p95 {:.3}, max {:.3}", nfe.makespan, s.median, s.p95, s.max);
    println!(
        "p95 degradation {:.1}% -> pad the deadline accordingly",
        (s.p95 / nfe.makespan - 1.0) * 100.0
    );
    Ok(())
}
