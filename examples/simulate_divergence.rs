//! Divergence-oracle walkthrough: replay an LP schedule on the cluster
//! engine, break it with injected adversity, and read the report.
//!
//! ```bash
//! cargo run --release --example simulate_divergence
//! ```
//!
//! The LP promises a makespan `T_f`; [`dlt::sim::replay`] *executes*
//! the schedule on the component-based discrete-event cluster
//! (`dlt::sim::cluster`) and reports what actually happened. This
//! example walks the full loop:
//!
//!   1. a clean Schedule-gated replay reproduces the LP's promise to
//!      fp accuracy (the oracle's acceptance bar);
//!   2. a mid-transfer processor failure breaks the promise — the
//!      `DivergenceReport` names every violated constraint and the
//!      per-processor slack shows exactly who ran late;
//!   3. pause-and-resume preemption vs lose-and-redo on the same
//!      window quantifies the cost of losing in-flight work;
//!   4. a synthetic 10 000-processor instance replays exactly, at
//!      scale, without touching the allocator in steady state.
//!
//! CLI equivalent of step 2:
//! `dlt simulate --spec spec.json --model nfe --fail p1@t=1.5+2 --json`

use dlt::dlt::no_frontend::NfeOptions;
use dlt::dlt::schedule::TimingModel;
use dlt::pipeline;
use dlt::model::SystemSpec;
use dlt::sim::cluster::{FaultSpec, InjectionPlan};
use dlt::sim::replay::{replay, synthetic_scale, DivergenceReport, ReplayOptions};

fn banner(title: &str, rep: &DivergenceReport) {
    println!("=== {title} ===");
    println!("  predicted T_f  = {:.6}", rep.predicted_makespan);
    println!("  simulated T_f  = {:.6}", rep.simulated_makespan);
    println!(
        "  rel gap        = {:+.3e}  ({} events, queue depth {})",
        rep.rel_gap,
        rep.events,
        rep.max_queue_depth
    );
    if rep.violated_constraints.is_empty() {
        println!("  promises       : all kept");
    } else {
        println!("  promises broken:");
        for v in &rep.violated_constraints {
            println!("    - {v}");
        }
    }
}

fn main() -> anyhow::Result<()> {
    dlt::util::logger::init();

    // Paper Table 2: G=(0.2,0.2), R=(0,5), A=(2,3,4), J=100 — the
    // paper's no-front-end numerical test.
    let spec = SystemSpec::builder()
        .source(0.2, 0.0)
        .source(0.2, 5.0)
        .processors(&[2.0, 3.0, 4.0])
        .job(100.0)
        .build()?;
    let sched = pipeline::solve(&NfeOptions::default(), &spec)?;

    // 1. Clean gated replay: sends start exactly at the LP's TS_{i,j},
    //    so the realized makespan must equal the promised one.
    let clean = replay(&spec, &sched, &ReplayOptions::default())?;
    banner("clean Schedule-gated replay", &clean);

    // 2. Take P1 down at t=1.5 for 2 time units, mid-transfer. The
    //    fault blocks its receives and loses its in-flight work; the
    //    oracle reports which LP promises the outage broke.
    let outage = ReplayOptions {
        plan: InjectionPlan {
            faults: vec![FaultSpec {
                processor: 0,
                at: 1.5,
                duration: Some(2.0),
                redo: true,
                blocks_recv: true,
            }],
            ..Default::default()
        },
        ..Default::default()
    };
    let faulted = replay(&spec, &sched, &outage)?;
    banner("P1 fails at t=1.5 for 2.0", &faulted);
    println!("  per-processor slack (negative = finished late):");
    for (j, s) in faulted.per_processor_slack.iter().enumerate() {
        println!("    P{}: {:+.4}", j + 1, s);
    }

    // 3. Preemption semantics on one window: pausing P1's compute for
    //    2 units mid-run vs losing the interrupted fraction entirely.
    let mid = sched.makespan * 0.6;
    let preempt = |redo: bool| ReplayOptions {
        plan: InjectionPlan {
            faults: vec![FaultSpec {
                processor: 0,
                at: mid,
                duration: Some(2.0),
                redo,
                blocks_recv: false,
            }],
            ..Default::default()
        },
        ..Default::default()
    };
    let resume = replay(&spec, &sched, &preempt(false))?;
    let redo = replay(&spec, &sched, &preempt(true))?;
    println!("=== preemption at t={mid:.3}, window 2.0 ===");
    println!("  clean            : {:.6}", clean.simulated_makespan);
    println!("  pause-and-resume : {:.6}", resume.simulated_makespan);
    println!("  lose-and-redo    : {:.6}", redo.simulated_makespan);

    // 4. Scale: a synthetic 10k-processor schedule (stamped from a
    //    nominal engine run) replays bit-exactly. The engine's flat
    //    arena and reserved tick heap keep the steady-state run
    //    allocation-free — see tests/sim_cluster_alloc.rs for the
    //    counting-allocator proof.
    let (big_spec, big_sched) = synthetic_scale(&spec, 10_000, TimingModel::NoFrontEnd)?;
    let big = replay(&big_spec, &big_sched, &ReplayOptions::default())?;
    banner("synthetic 10 000-processor gated replay", &big);

    Ok(())
}
