#!/usr/bin/env python3
"""Minimal `dlt serve` wire client — stdlib only.

Boot a server:

    dlt serve --port 4517

then run:

    python3 examples/serve_client.py --port 4517 --count 5

The wire is one JSON document per line over a persistent TCP
connection. Each request may carry a top-level "client" key: all of a
client's requests hash to the same session shard, so its warm-start
caches stay hot across requests (watch `diagnostics.serve.shard_hit`
flip to true from the second request on). Responses stream back in
completion order, each stamped with a per-connection "seq"; an
overloaded server answers instantly with
`{"error": {"kind": "overloaded", ...}, "retry_after_ms": ...}`.
"""

import argparse
import json
import socket
import sys

SPEC = {
    "sources": [{"g": 0.2, "release": 10.0}, {"g": 0.4, "release": 50.0}],
    "processors": [{"a": 2.0}, {"a": 3.0}, {"a": 4.0}],
    "job": 100.0,
}

FAMILIES = ["frontend", "no_frontend", "concurrent", "multi_job"]


def build_request(client, k):
    req = {
        "client": client,
        "id": f"{client}-{k}",
        "family": FAMILIES[k % len(FAMILIES)],
        "spec": dict(SPEC, job=100.0 + 25.0 * k),
        "options": {},
    }
    if req["family"] == "multi_job":
        req["options"]["proc_ready"] = [0.25] * len(SPEC["processors"])
    return req


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, default=4517)
    ap.add_argument("--count", type=int, default=5, help="requests to send")
    ap.add_argument("--client", default="example-client", help="tenant key")
    args = ap.parse_args()

    with socket.create_connection((args.host, args.port), timeout=30) as sock:
        sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
        wire = sock.makefile("rw", encoding="utf-8", newline="\n")

        # Pipeline every request, then read the streamed responses.
        for k in range(args.count):
            wire.write(json.dumps(build_request(args.client, k)) + "\n")
        wire.flush()

        failures = 0
        for _ in range(args.count):
            line = wire.readline()
            if not line:
                print("server closed the connection early", file=sys.stderr)
                return 1
            resp = json.loads(line)
            seq = resp.get("seq")
            if "error" in resp:
                failures += 1
                retry = resp.get("retry_after_ms")
                hint = f" (retry after {retry}ms)" if retry is not None else ""
                print(f"seq {seq}: {resp['error']['kind']}: "
                      f"{resp['error']['message']}{hint}")
                continue
            serve = resp.get("diagnostics", {}).get("serve", {})
            print(f"seq {seq}: {resp['family']:<12} makespan {resp['makespan']:.4f}  "
                  f"shard {serve.get('shard')} "
                  f"{'hit' if serve.get('shard_hit') else 'miss'}  "
                  f"resident {serve.get('resident')}")
        return 1 if failures == args.count else 0


if __name__ == "__main__":
    sys.exit(main())
