//! Regenerate every table and figure in the paper's evaluation.
//!
//! ```bash
//! cargo run --release --example reproduce_paper            # all figures
//! cargo run --release --example reproduce_paper fig15      # one figure
//! cargo run --release --example reproduce_paper all out/   # + CSV files
//! ```
//!
//! Prints the same rows/series the paper reports, with anchor notes
//! comparing our values against the numbers printed in the paper text
//! (Figs. 15, 16, 18). See EXPERIMENTS.md for the recorded comparison.

use dlt::experiments::{run, ALL};

fn main() -> anyhow::Result<()> {
    dlt::util::logger::init();
    let args: Vec<String> = std::env::args().skip(1).collect();
    let which = args.first().map(String::as_str).unwrap_or("all");
    let csv_dir = args.get(1).cloned();

    let names: Vec<&str> =
        if which == "all" { ALL.to_vec() } else { vec![which] };

    for name in names {
        let t = run(name)?;
        println!("{}", t.render_text());
        if let Some(dir) = &csv_dir {
            let path = t.write_csv(dir)?;
            println!("  wrote {path}\n");
        }
    }
    Ok(())
}
