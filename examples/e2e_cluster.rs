//! END-TO-END DRIVER: the full system on a real small workload.
//!
//! ```bash
//! make artifacts && cargo run --release --example e2e_cluster
//! ```
//!
//! Composes all three layers:
//!   1. Layer 3 solves the §3.1 LP for a 3-source × 8-processor system
//!      (the paper's scheduling contribution).
//!   2. The schedule is executed on the threaded cluster runtime:
//!      source threads stream the job's bytes through rate-limited
//!      links under the paper's sequential-communication rules.
//!   3. Each processor thread does REAL compute per received fraction
//!      by executing the AOT-compiled Pallas workload kernel through
//!      PJRT (`artifacts/workload_r128_c128.hlo.txt`), calibrated so
//!      one load unit on P_j costs `A_j * time_scale` wall seconds.
//!
//! Reported: LP-predicted vs realized makespan, per-processor load and
//! utilization, and the multi-source speedup headline (3 sources vs 1)
//! — the paper's core claim, measured on real execution instead of a
//! timing model. Falls back to modeled compute when artifacts are
//! missing. Results recorded in EXPERIMENTS.md §End-to-end.

use dlt::cluster::{run_cluster, ClusterConfig, Compute};
use dlt::dlt::frontend::FeOptions;
use dlt::pipeline;
use dlt::model::SystemSpec;
use dlt::runtime::{Runtime, WorkloadExecutable};
use std::sync::Arc;

fn spec(n_sources: usize) -> SystemSpec {
    let mut b = SystemSpec::builder();
    let gs = [0.20, 0.24, 0.28]; // link-bound: distribution dominates
    for i in 0..n_sources {
        b = b.source(gs[i], 0.5 * i as f64);
    }
    b.processors(&[1.0, 1.1, 1.3, 1.5, 1.8, 2.1, 2.5, 3.0]).job(100.0).build().unwrap()
}

/// Paced real compute: each received fraction's modeled compute budget
/// is `load · A_j · time_scale` wall seconds. A fraction of that budget
/// is filled with actual PJRT kernel executions (calibrated
/// single-threaded); the remainder is slept. This keeps all three
/// layers genuinely executing while staying faithful to the timing
/// model even when M concurrent processor threads contend for cores —
/// any overrun degrades the realized makespan and is visible in the
/// reported relative error.
///
/// The duty cycle is scaled to the machine: M virtual processors must
/// share `cores` real ones, so each gets at most `~0.6 * cores / M` of
/// its wall-time budget as real compute.
fn real_fraction(m: usize) -> f64 {
    let cores = std::thread::available_parallelism().map(|c| c.get()).unwrap_or(1);
    (0.15 * cores as f64 / m as f64).min(0.25)
}

fn real_compute(a: Vec<f64>, scale: f64, sec_per_unit: f64) -> Compute {
    let duty = real_fraction(a.len());
    Compute::Custom(Arc::new(move |j: usize| {
        // Runs inside processor j's thread: it owns its own PJRT
        // client (PjRtClient is not Send).
        let mut w = WorkloadExecutable::open("artifacts", 42)
            .expect("open workload artifact in processor thread");
        let aj = a[j];
        let mut checksum = 0.0f64;
        Box::new(move |load: f64| {
            let budget = load * aj * scale; // wall secs for this fraction
            let t0 = std::time::Instant::now();
            let units = (budget * duty / sec_per_unit).floor() as usize;
            checksum += w.run_units(units).expect("workload execution");
            std::hint::black_box(checksum);
            let elapsed = t0.elapsed().as_secs_f64();
            if elapsed < budget {
                std::thread::sleep(std::time::Duration::from_secs_f64(budget - elapsed));
            }
        })
    }))
}

fn main() -> anyhow::Result<()> {
    dlt::util::logger::init();
    let time_scale = 0.05; // 50 ms of wall clock per model time unit

    // Calibrate the real kernel once (if artifacts exist).
    let calibration = if Runtime::artifacts_available() {
        let mut probe = WorkloadExecutable::open("artifacts", 42)?;
        let sec = probe.calibrate(16)?;
        println!(
            "workload kernel: {:.3} ms / unit ({}x{} chunk through PJRT)",
            sec * 1e3,
            probe.rows,
            probe.cols
        );
        Some(sec)
    } else {
        println!("NOTE: artifacts/ missing -> modeled compute (run `make artifacts` for real compute)");
        None
    };

    let mut results = Vec::new();
    for n in [1usize, 3] {
        let s = spec(n);
        let sched = pipeline::solve(&FeOptions::default(), &s)?;
        let compute = match calibration {
            Some(sec) => real_compute(s.a(), time_scale, sec),
            None => Compute::Modeled,
        };
        let cfg = ClusterConfig { time_scale, compute, fe_splits: 8 };
        println!("\n=== {n}-source cluster (8 processors, J=100) ===");
        println!("LP predicted T_f = {:.4}", sched.makespan);
        let rep = run_cluster(&s, &sched, &cfg)?;
        println!("realized T_f     = {:.4}  ({:+.2}% vs predicted)", rep.realized_makespan, rep.relative_error * 100.0);
        println!("wall clock       = {:?}", rep.wall);
        for j in 0..s.m() {
            println!(
                "  P{}: load {:7.3}  busy {:6.1}%  done at {:.3}",
                j + 1,
                rep.proc_load[j],
                100.0 * rep.proc_load[j] * s.a()[j] / rep.realized_makespan,
                rep.proc_done[j]
            );
        }
        results.push((n, sched.makespan, rep.realized_makespan));
    }

    let (_, pred1, real1) = results[0];
    let (_, pred3, real3) = results[1];
    println!("\n=== headline (paper §5: multi-source speedup) ===");
    println!("predicted speedup 3 sources vs 1: {:.2}x", pred1 / pred3);
    println!("realized  speedup 3 sources vs 1: {:.2}x", real1 / real3);
    Ok(())
}
