//! §6 trade-off advisor walkthrough — the paper's three user stories
//! on the Table 5 system.
//!
//! ```bash
//! cargo run --release --example tradeoff_advisor
//! ```

use dlt::cost::{advise, Advice, Budgets, TradeoffTable};
use dlt::experiments::params;

fn show(label: &str, advice: &Advice) {
    match advice {
        Advice::Use { m, tf, cost } => {
            println!("{label}: use {m} processors  (T_f {tf:.2}, cost ${cost:.2})")
        }
        Advice::Range { lo, hi, recommended } => println!(
            "{label}: any m in [{lo}, {hi}] works; cheapest is m = {recommended}"
        ),
        Advice::Infeasible { min_cost_meeting_time, min_time_within_cost } => {
            println!("{label}: INFEASIBLE");
            if let Some(c) = min_cost_meeting_time {
                println!("   -> meeting the deadline needs >= ${c:.2}");
            }
            if let Some(t) = min_time_within_cost {
                println!("   -> staying in budget needs a deadline >= {t:.2}");
            }
        }
    }
}

fn main() -> anyhow::Result<()> {
    dlt::util::logger::init();
    let spec = params::table5();
    let sweep = TradeoffTable::sweep(&spec)?;

    println!("{:>4} {:>10} {:>10} {:>10}", "m", "T_f", "cost", "grad %");
    for (k, p) in sweep.points.iter().enumerate() {
        let g = if k == 0 {
            String::new()
        } else {
            format!("{:+.2}", sweep.gradients[k - 1] * 100.0)
        };
        println!("{:>4} {:>10.3} {:>10.2} {:>10}", p.m, p.tf, p.cost, g);
    }
    println!();

    // §6.2 — the paper's worked example: budget $3450, 6% rule -> m=5.
    let s1 = advise(
        &sweep,
        &Budgets { cost: Some(3450.0), time: None, gradient_threshold: 0.06 },
    );
    show("cost budget $3450 + 6% gradient rule (paper §6.2)", &s1);

    // §6.3 — deadline of 32 s -> paper picks m = 10.
    let s2 = advise(&sweep, &Budgets { cost: None, time: Some(32.0), gradient_threshold: 0.0 });
    show("time budget 32s (paper §6.3)", &s2);

    // §6.4 case 1 — overlapping areas (Fig. 19).
    let s3 = advise(
        &sweep,
        &Budgets {
            cost: Some(sweep.at(12).cost),
            time: Some(sweep.at(6).tf),
            gradient_threshold: 0.06,
        },
    );
    show("both budgets, overlap (Fig. 19)", &s3);

    // §6.4 case 2 — disjoint areas (Fig. 20).
    let s4 = advise(
        &sweep,
        &Budgets {
            cost: Some(sweep.at(4).cost),
            time: Some(sweep.at(10).tf),
            gradient_threshold: 0.06,
        },
    );
    show("both budgets, no overlap (Fig. 20)", &s4);

    Ok(())
}
