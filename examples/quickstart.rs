//! Quickstart: solve one multi-source scheduling instance end to end.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```
//!
//! Solves the paper's two numerical tests (Table 1 with front-ends,
//! Table 2 without), validates the schedules, and cross-checks them on
//! the discrete-event simulator.

use dlt::dlt::frontend::FeOptions;
use dlt::dlt::no_frontend::NfeOptions;
use dlt::dlt::schedule::TimingModel;
use dlt::dlt::validate;
use dlt::pipeline;
use dlt::model::SystemSpec;
use dlt::sim::{simulate, SimOptions};

fn main() -> anyhow::Result<()> {
    dlt::util::logger::init();

    // Paper Table 1: G=(0.2,0.4), R=(10,50), A=(2..6), J=100.
    let table1 = SystemSpec::builder()
        .source(0.2, 10.0)
        .source(0.4, 50.0)
        .processors(&[2.0, 3.0, 4.0, 5.0, 6.0])
        .job(100.0)
        .build()?;

    println!("=== Table 1, with front-ends (§3.1) ===");
    let fe = pipeline::solve(&FeOptions::default(), &table1)?;
    println!("T_f = {:.4}  ({} simplex iterations)", fe.makespan, fe.lp_iterations);
    print!("{}", fe.render_beta_table());
    let report = validate(&table1, &fe);
    println!("validation: {}\n", if report.is_valid() { "OK" } else { "FAILED" });

    // Paper Table 2: G=(0.2,0.2), R=(0,5), A=(2,3,4), J=100.
    // (Table 1's release gap R_2-R_1 = 40 makes the §3.2 LP infeasible:
    // eq. 12 would force beta_{1,1} >= 200 > J. The paper runs its
    // no-front-end test on Table 2 for exactly this reason; see the
    // infeasibility demo below.)
    let table2 = SystemSpec::builder()
        .source(0.2, 0.0)
        .source(0.2, 5.0)
        .processors(&[2.0, 3.0, 4.0])
        .job(100.0)
        .build()?;

    println!("=== Table 2, without front-ends (§3.2) ===");
    let nfe = pipeline::solve(&NfeOptions::default(), &table2)?;
    println!("T_f = {:.4}  ({} simplex iterations)", nfe.makespan, nfe.lp_iterations);
    print!("{}", nfe.render_beta_table());
    let report = validate(&table2, &nfe);
    println!("validation: {}\n", if report.is_valid() { "OK" } else { "FAILED" });

    // Independent check: execute both schedules on the DES.
    for (name, spec, sched, model) in [
        ("Table 1 FE", &table1, &fe, TimingModel::FrontEnd),
        ("Table 2 NFE", &table2, &nfe, TimingModel::NoFrontEnd),
    ] {
        let res = simulate(spec, &sched.beta, &SimOptions { model, ..Default::default() });
        println!(
            "DES check ({name}): LP T_f {:.4} vs simulated {:.4}",
            sched.makespan, res.makespan
        );
    }

    // FE vs NFE on the same system: front-ends can only help.
    let fe2 = pipeline::solve(&FeOptions::default(), &table2)?;
    println!(
        "\nTable 2 with front-ends would finish in {:.4} ({:.1}% faster)",
        fe2.makespan,
        (1.0 - fe2.makespan / nfe.makespan) * 100.0
    );

    // The infeasibility the paper implicitly sidesteps: Table 1's
    // release times under the §3.2 constraints (keep S1 busy until S2's
    // release — eq. 12) cannot be satisfied with J = 100.
    match pipeline::solve(&NfeOptions::default(), &table1) {
        Err(e) => println!("\nTable 1 under §3.2 is infeasible as expected: {e}"),
        Ok(s) => println!("\nunexpected: Table 1 NFE solved with T_f {}", s.makespan),
    }
    // Dropping eq. 12 restores feasibility.
    let relaxed = pipeline::solve(
        &NfeOptions { drop_source_busy_constraint: true, ..Default::default() },
        &table1,
    )?;
    println!("...and solvable without eq. 12: T_f = {:.4}", relaxed.makespan);

    // Hypersparse hot path: re-solve a warm job sweep through the api
    // facade with Bartels-Golub basis updates and candidate-list
    // partial pricing (`--factorization bartels_golub --pricing
    // partial` on the CLI) and read the new diagnostics — window hits
    // vs full-pass refreshes, how sparse the per-iteration FTRAN/BTRAN
    // results actually stayed, and how many sparse solves took the
    // Gilbert-Peierls symbolic DFS path vs the full column sweep.
    use dlt::api::{Family, SolveRequest, Solver};
    use dlt::lp::{Factorization, Pricing, SimplexOptions};
    let mut session = Solver::new()
        .simplex(SimplexOptions {
            factorization: Factorization::BartelsGolub,
            pricing: Pricing::Partial,
            ..SimplexOptions::default()
        })
        .build();
    println!("\n=== Warm sweep, Bartels-Golub + partial pricing (hypersparse diagnostics) ===");
    for k in 0..4 {
        let sub = table1.with_job(100.0 + 25.0 * k as f64);
        let resp = session
            .solve(&SolveRequest::new(Family::Frontend, sub))
            .map_err(|e| e.into_error())?;
        let d = &resp.diagnostics;
        println!(
            "J={:6.1}: T_f {:.4}  ({} iters, warm={}, candidate hits {}, refreshes {}, \
             avg ftran/btran nnz {:.1}/{:.1}, dfs/scan solves {}/{})",
            100.0 + 25.0 * k as f64,
            resp.makespan,
            d.iterations,
            d.warm_start,
            d.candidate_hits,
            d.candidate_refreshes,
            d.avg_ftran_nnz,
            d.avg_btran_nnz,
            d.dfs_solves,
            d.scan_solves
        );
    }
    Ok(())
}
