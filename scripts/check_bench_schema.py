#!/usr/bin/env python3
"""Validate bench JSON artifacts and apply the regression gates.

Usage: check_bench_schema.py FILE.json [FILE.json ...]

Two layers, both fatal on failure:

1. Schema: every value in every document must be present and non-null
   (a bench that emits a missing or null cell fails loudly here
   instead of silently passing a gate that never reads the cell).
   NaN/Infinity — which Python's json module would happily accept —
   are rejected too.

2. Gates, dispatched on the document's "group":
   - hypersparse: the deterministic regression guards over the
     measured cells — the sparse warm sweep against the dense baseline
     cell, factor storage against the dense 2m^2 equivalent, and the
     Gilbert-Peierls DFS work counter against the column-sweep scan on
     the same solve.
   - sim: the cluster replay-engine guards — engine cells must cover
     the 100 / 1k / 10k processor scales with positive event counts
     and throughput, a jitter-free gated replay must reproduce the
     stamped makespan exactly (rel_gap == 0.0, bit-for-bit), the
     cluster-vs-legacy overhead ratio must be positive, and the
     fault-duration sweep must be monotone (longer outages never
     finish earlier).
   - serve: the serving-tier load-harness guards — sustained
     throughput positive with ordered finite latency percentiles, a
     warm-shard hit rate above zero under client-keyed load, shed rate
     below 100%; 2x overload must fast-reject (shed rate > 0) while
     the accepted requests keep a finite p99; the 64-client probe must
     force LRU evictions.
   - pdhg: the first-order tier guards — the sparse CSC matvec must
     beat the dense row-major matvec >= 4x on the largest cell, the
     width-16 block panel must deliver >= 2x sequential PDHG
     throughput, the hybrid sweep's crossover-cleanup pivot total must
     not exceed the cold-simplex pivot total, and knee refinement must
     localize a non-degenerate bracket in fewer solves than the
     equivalent uniform fine grid.
   - robustness: the fail-operational guards — the amortized deadline
     check must cost <= 2% on the warm hot path, a corrupted warm
     basis must record at least one recovery event while falling back
     cold, and a non-converging solve under a wall-clock deadline must
     return the typed error within 2x the deadline.

Exit status is non-zero on the first violation.
"""

import json
import math
import sys

# Cells/sections a BENCH_hypersparse.json must carry, per entry.
HYPERSPARSE_MICRO_KEYS = {
    "strategy", "dense_is_adapter", "m",
    "ftran_dense_ns", "ftran_sparse_ns", "btran_dense_ns", "btran_sparse_ns",
    "storage_nnz", "dense_equivalent_entries",
}
HYPERSPARSE_GP_KEYS = {
    "kernel", "m", "dfs_ns", "scan_ns", "dfs_work", "scan_work", "result_nnz",
}
HYPERSPARSE_CELL_KEYS = {
    "cell", "backend", "factorization", "pricing",
    "cold_ms", "cold_iterations", "sweep_ms", "sweep_iterations",
    "candidate_hits", "candidate_refreshes", "avg_ftran_nnz",
}
HYPERSPARSE_STRATEGIES = {
    "product_form_eta", "forrest_tomlin", "markowitz", "bartels_golub",
}
HYPERSPARSE_SWEEP_CELLS = {
    "dense_tableau/full", "revised/full", "revised/partial",
    "revised/ft/partial", "revised/bg/partial",
}


def fail(msg):
    print(f"check_bench_schema: FAIL: {msg}", file=sys.stderr)
    sys.exit(1)


def check_no_null(node, path):
    """Reject None and non-finite numbers anywhere in the document."""
    if node is None:
        fail(f"null value at {path}")
    if isinstance(node, float) and not math.isfinite(node):
        fail(f"non-finite value at {path}")
    if isinstance(node, dict):
        for k, v in node.items():
            check_no_null(v, f"{path}.{k}")
    elif isinstance(node, list):
        for i, v in enumerate(node):
            check_no_null(v, f"{path}[{i}]")


def require_keys(entry, keys, where):
    missing = keys - set(entry)
    if missing:
        fail(f"{where}: missing keys {sorted(missing)}")


def gate_hypersparse(doc, name):
    micro = doc.get("micro_kernels")
    if not micro:
        fail(f"{name}: empty micro_kernels")
    seen = set()
    for k in micro:
        require_keys(k, HYPERSPARSE_MICRO_KEYS, f"{name}: micro_kernels[{k.get('strategy')}]")
        seen.add(k["strategy"])
        if k["storage_nnz"] * 4 >= k["dense_equivalent_entries"]:
            fail(f"{name}: {k['strategy']}: factor storage {k['storage_nnz']} entries "
                 f"is no longer sparse (dense pair {k['dense_equivalent_entries']})")
    if seen != HYPERSPARSE_STRATEGIES:
        fail(f"{name}: micro_kernels strategies {sorted(seen)} != "
             f"{sorted(HYPERSPARSE_STRATEGIES)}")

    gp = doc.get("gp_kernels")
    if not gp:
        fail(f"{name}: empty gp_kernels")
    kernels = set()
    for g in gp:
        require_keys(g, HYPERSPARSE_GP_KEYS, f"{name}: gp_kernels[{g.get('kernel')}]")
        kernels.add(g["kernel"])
        # Deterministic work gate: the symbolic DFS must visit strictly
        # fewer nodes than the full column sweep on the same solve.
        if g["dfs_work"] >= g["scan_work"]:
            fail(f"{name}: gp {g['kernel']}: DFS visited {g['dfs_work']} nodes, "
                 f"no better than the {g['scan_work']}-node column sweep")
        if g["result_nnz"] <= 0:
            fail(f"{name}: gp {g['kernel']}: solve produced an empty result")
    if kernels != {"ftran", "btran"}:
        fail(f"{name}: gp_kernels covers {sorted(kernels)}, want ftran+btran")

    cells = {}
    for c in doc.get("sweep_cells", []):
        require_keys(c, HYPERSPARSE_CELL_KEYS, f"{name}: sweep_cells[{c.get('cell')}]")
        cells[c["cell"]] = c
    missing = HYPERSPARSE_SWEEP_CELLS - set(cells)
    if missing:
        fail(f"{name}: missing sweep cells {sorted(missing)}")
    for c in cells.values():
        if c["sweep_iterations"] <= 0:
            fail(f"{name}: {c['cell']}: sweep did not pivot")

    dense, sparse = cells["dense_tableau/full"], cells["revised/partial"]
    # 1.5x slack: fast-mode totals are sub-millisecond, where
    # shared-runner jitter is a real fraction of the measurement.
    if sparse["sweep_ms"] > dense["sweep_ms"] * 1.5:
        fail(f"{name}: sparse warm sweep {sparse['sweep_ms']:.2f}ms slower than "
             f"dense baseline cell {dense['sweep_ms']:.2f}ms")
    ft, bg = cells["revised/ft/partial"], cells["revised/bg/partial"]
    print(f"  gate ok: dense {dense['sweep_ms']:.2f}ms vs sparse+partial "
          f"{sparse['sweep_ms']:.2f}ms; update-file race ft {ft['sweep_ms']:.2f}ms "
          f"vs bg {bg['sweep_ms']:.2f}ms")


# Cells/sections a BENCH_sim.json must carry.
SIM_CELL_KEYS = {
    "m", "n", "events", "max_queue_depth", "wall_ns", "events_per_sec",
    "makespan", "rel_gap",
}
SIM_SCALES = {100, 1000, 10000}
SIM_OVERHEAD_KEYS = {"legacy_ns", "cluster_ns", "ratio"}


def gate_sim(doc, name):
    cells = {}
    for c in doc.get("engine_cells", []):
        require_keys(c, SIM_CELL_KEYS, f"{name}: engine_cells[m={c.get('m')}]")
        cells[c["m"]] = c
    missing = SIM_SCALES - set(cells)
    if missing:
        fail(f"{name}: engine cells missing scales {sorted(missing)}")
    for c in cells.values():
        if c["events"] <= 0:
            fail(f"{name}: m={c['m']}: replay processed no events")
        if c["events_per_sec"] <= 0:
            fail(f"{name}: m={c['m']}: non-positive throughput")
        if c["makespan"] <= 0:
            fail(f"{name}: m={c['m']}: non-positive makespan")
        # Determinism contract: a jitter-free fault-free gated replay
        # reproduces the stamped makespan bit-for-bit, so the gate is
        # exact zero, not a tolerance.
        if c["rel_gap"] != 0.0:
            fail(f"{name}: m={c['m']}: jitter-free replay drifted "
                 f"(rel_gap {c['rel_gap']:+.3e})")

    over = doc.get("replay_overhead")
    if not over:
        fail(f"{name}: missing replay_overhead")
    require_keys(over, SIM_OVERHEAD_KEYS, f"{name}: replay_overhead")
    if over["ratio"] <= 0:
        fail(f"{name}: replay_overhead ratio {over['ratio']} not positive")

    sweep = doc.get("fault_sweep")
    if not sweep:
        fail(f"{name}: missing fault_sweep")
    spans = sweep.get("makespans")
    if not spans or len(spans) < 2:
        fail(f"{name}: fault_sweep needs at least two makespans")
    for a, b in zip(spans, spans[1:]):
        if b < a:
            fail(f"{name}: fault sweep not monotone: a longer outage "
                 f"finished earlier ({b} < {a})")

    big = cells[10000]
    print(f"  gate ok: 10k-processor replay {big['events']:.0f} events at "
          f"{big['events_per_sec'] / 1e6:.2f}M events/s, rel_gap exactly 0; "
          f"cluster/legacy overhead {over['ratio']:.2f}x; fault sweep monotone")


# Cells every phase object in a BENCH_serve.json must carry.
SERVE_PHASE_KEYS = {
    "offered", "accepted", "shed", "errors", "lost", "wall_s", "req_s",
    "shed_rate", "p50_ms", "p99_ms", "p999_ms", "warm_shard_hit_rate",
    "evictions_seen", "max_resident",
}
SERVE_PHASES = {"calibrate", "sustained", "overload", "eviction_probe"}


def gate_serve(doc, name):
    for phase in SERVE_PHASES:
        entry = doc.get(phase)
        if not entry:
            fail(f"{name}: missing phase `{phase}`")
        require_keys(entry, SERVE_PHASE_KEYS, f"{name}: {phase}")
        if entry["lost"] != 0:
            fail(f"{name}: {phase}: {entry['lost']} requests never got a response line")

    sus = doc["sustained"]
    if sus["req_s"] <= 0:
        fail(f"{name}: sustained throughput is {sus['req_s']} req/s")
    if not (0 < sus["p50_ms"] <= sus["p99_ms"] <= sus["p999_ms"]):
        fail(f"{name}: sustained latency percentiles not ordered/positive: "
             f"p50 {sus['p50_ms']}, p99 {sus['p99_ms']}, p999 {sus['p999_ms']}")
    if sus["warm_shard_hit_rate"] <= 0:
        fail(f"{name}: client-keyed sustained load never hit a warm shard")
    if sus["shed_rate"] >= 1.0:
        fail(f"{name}: sustained load was entirely shed")

    over = doc["overload"]
    if over["shed"] <= 0:
        fail(f"{name}: 2x overload shed nothing — admission control inert")
    if over["accepted"] <= 0 or over.get("accepted_p99_ms", 0) <= 0:
        fail(f"{name}: 2x overload starved every accepted request")

    probe = doc["eviction_probe"]
    if probe["evictions_seen"] <= 0:
        fail(f"{name}: eviction probe forced no LRU evictions")

    print(f"  gate ok: sustained {sus['req_s']:.0f} req/s "
          f"(p99 {sus['p99_ms']:.2f}ms, warm hits {sus['warm_shard_hit_rate']:.0%}); "
          f"overload shed {over['shed_rate']:.0%} with accepted p99 "
          f"{over['accepted_p99_ms']:.2f}ms; probe evicted {probe['evictions_seen']}")


# Cells/sections a BENCH_pdhg_hybrid.json must carry.
PDHG_MATVEC_KEYS = {
    "cell", "rows", "vars", "nnz", "dense_ns", "sparse_ns", "speedup",
}
PDHG_BLOCK_KEYS = {
    "width", "sequential_ms", "block_ms", "throughput_ratio", "columns_retired",
}
PDHG_HYBRID_KEYS = {
    "sweep_points", "hybrid_cleanup_pivots", "hybrid_stage_blocks",
    "cold_simplex_pivots", "hybrid_ms", "cold_ms",
}
PDHG_REFINE_KEYS = {
    "coarse_points", "threshold", "tol", "refine_solves",
    "fine_grid_equivalent", "knee_lo", "knee_hi",
}


def gate_pdhg(doc, name):
    cells = doc.get("matvec_cells")
    if not cells:
        fail(f"{name}: empty matvec_cells")
    for c in cells:
        require_keys(c, PDHG_MATVEC_KEYS, f"{name}: matvec_cells[{c.get('cell')}]")
        if c["nnz"] <= 0:
            fail(f"{name}: {c['cell']}: empty constraint matrix")
    largest = max(cells, key=lambda c: c["rows"] * c["vars"])
    # The scheduling matrices are overwhelmingly sparse; the CSC kernel
    # must beat a dense row-major matvec by a wide margin where it
    # matters most.
    if largest["speedup"] < 4.0:
        fail(f"{name}: {largest['cell']}: sparse matvec only "
             f"{largest['speedup']:.1f}x dense, need >= 4x")

    blocks = {}
    for c in doc.get("block_cells", []):
        require_keys(c, PDHG_BLOCK_KEYS, f"{name}: block_cells[width={c.get('width')}]")
        blocks[c["width"]] = c
    if 16 not in blocks:
        fail(f"{name}: block_cells missing the width-16 panel")
    wide = blocks[16]
    if wide["throughput_ratio"] < 2.0:
        fail(f"{name}: block-of-16 only {wide['throughput_ratio']:.2f}x "
             f"sequential PDHG throughput, need >= 2x")

    hy = doc.get("hybrid")
    if not hy:
        fail(f"{name}: missing hybrid section")
    require_keys(hy, PDHG_HYBRID_KEYS, f"{name}: hybrid")
    if hy["hybrid_cleanup_pivots"] > hy["cold_simplex_pivots"]:
        fail(f"{name}: hybrid cleanup spent {hy['hybrid_cleanup_pivots']} pivots, "
             f"more than the {hy['cold_simplex_pivots']} cold-simplex pivots")

    ref = doc.get("refine")
    if not ref:
        fail(f"{name}: missing refine section")
    require_keys(ref, PDHG_REFINE_KEYS, f"{name}: refine")
    if not ref["knee_lo"] < ref["knee_hi"]:
        fail(f"{name}: degenerate knee bracket [{ref['knee_lo']}, {ref['knee_hi']}]")
    if ref["refine_solves"] >= ref["fine_grid_equivalent"]:
        fail(f"{name}: refinement spent {ref['refine_solves']} solves, no better "
             f"than the {ref['fine_grid_equivalent']}-point uniform grid")

    print(f"  gate ok: sparse matvec {largest['speedup']:.1f}x dense on "
          f"{largest['cell']}; block-of-16 {wide['throughput_ratio']:.2f}x sequential; "
          f"hybrid cleanup {hy['hybrid_cleanup_pivots']} vs cold "
          f"{hy['cold_simplex_pivots']} pivots; knee in {ref['refine_solves']} solves")


# Sections a BENCH_robustness.json must carry.
ROBUSTNESS_OVERHEAD_KEYS = {"solves", "baseline_ms", "budgeted_ms", "overhead_pct"}
ROBUSTNESS_LADDER_KEYS = {"cold_ms", "engage_ms", "recovery_events_count"}
ROBUSTNESS_DEADLINE_KEYS = {"timeout_ms", "observed_ms", "within_factor", "typed_error"}


def gate_robustness(doc, name):
    over = doc.get("deadline_overhead")
    if not over:
        fail(f"{name}: missing deadline_overhead section")
    require_keys(over, ROBUSTNESS_OVERHEAD_KEYS, f"{name}: deadline_overhead")
    if over["baseline_ms"] <= 0 or over["budgeted_ms"] <= 0:
        fail(f"{name}: deadline_overhead sweeps did not run")
    # The amortized check is one integer branch per pivot plus a rare
    # clock read; the warm hot path must not feel it.
    if over["overhead_pct"] > 2.0:
        fail(f"{name}: deadline checks cost {over['overhead_pct']:.2f}% on the "
             f"warm hot path, budget is <= 2%")

    ladder = doc.get("ladder")
    if not ladder:
        fail(f"{name}: missing ladder section")
    require_keys(ladder, ROBUSTNESS_LADDER_KEYS, f"{name}: ladder")
    if ladder["recovery_events_count"] <= 0:
        fail(f"{name}: corrupted warm basis recorded no recovery events")
    if ladder["engage_ms"] <= 0:
        fail(f"{name}: ladder engagement was not measured")

    dl = doc.get("deadline_honored")
    if not dl:
        fail(f"{name}: missing deadline_honored section")
    require_keys(dl, ROBUSTNESS_DEADLINE_KEYS, f"{name}: deadline_honored")
    if not dl["typed_error"]:
        fail(f"{name}: non-converging solve under deadline did not return "
             f"the typed DeadlineExceeded error")
    if dl["within_factor"] > 2.0:
        fail(f"{name}: deadline honored only within {dl['within_factor']:.2f}x "
             f"of the {dl['timeout_ms']}ms budget, need <= 2x")

    print(f"  gate ok: deadline checks {over['overhead_pct']:+.2f}% on "
          f"{over['solves']:.0f} warm solves; recovery recorded "
          f"{ladder['recovery_events_count']:.0f} event(s) at "
          f"{ladder['engage_ms']:.3f}ms; {dl['timeout_ms']:.0f}ms deadline honored "
          f"within {dl['within_factor']:.2f}x")


def reject_nonfinite(token):
    fail(f"non-finite literal `{token}` in document")


def main(paths):
    if not paths:
        fail("no bench JSON files given")
    for path in paths:
        try:
            with open(path) as fh:
                doc = json.load(fh, parse_constant=reject_nonfinite)
        except (OSError, ValueError) as e:
            fail(f"{path}: {e}")
        check_no_null(doc, path)
        if doc.get("group") == "hypersparse":
            gate_hypersparse(doc, path)
        if doc.get("group") == "serve":
            gate_serve(doc, path)
        if doc.get("group") == "sim":
            gate_sim(doc, path)
        if doc.get("group") == "pdhg":
            gate_pdhg(doc, path)
        if doc.get("group") == "robustness":
            gate_robustness(doc, path)
        print(f"check_bench_schema: {path}: ok")


if __name__ == "__main__":
    main(sys.argv[1:])
