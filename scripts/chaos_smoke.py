#!/usr/bin/env python3
"""Chaos smoke against a live `dlt serve` — stdlib only.

Boot a server (CI boots it with `--degraded --queue-depth 16
--default-timeout-ms 2000`), then run:

    python3 scripts/chaos_smoke.py --port 4519 --clients 8 --requests 40

Each client thread drives one persistent connection with a seeded
random mix of traffic: normal solves across all four families, solves
carrying a real `timeout_ms` deadline, zero-deadline solves that must
come back as typed `deadline_exceeded` (or `degraded: true` when the
server absorbs them), malformed/garbage lines, an oversize frame, and
`{"reload": {...}}` admin frames swapping server knobs mid-load. Two
extra connections disconnect abruptly mid-stream without reading.

Hard gates (non-zero exit on the first violation):

- lost == 0: every frame sent on a surviving connection receives
  exactly one response line (the per-connection `seq` stamps must
  cover the send order with no gaps).
- every shed (`overloaded`) response carries a finite
  `retry_after_ms` in [1, 60000].
- every deadline-cohort response arrives within 2x its deadline of
  being sent (success, `degraded: true`, or `deadline_exceeded`).
- at least one response across the run is `deadline_exceeded` or
  `degraded: true` (the end-to-end deadline proof).
- every reload frame is acknowledged with a `reloaded` echo.
- clean drain: after the chaos, a fresh connection still gets a
  correct solve from the same server.
"""

import argparse
import json
import random
import socket
import sys
import threading
import time

SPEC = {
    "sources": [{"g": 0.2, "release": 10.0}, {"g": 0.4, "release": 50.0}],
    "processors": [{"a": 2.0}, {"a": 3.0}, {"a": 4.0}],
    "job": 100.0,
}

FAMILIES = ["frontend", "no_frontend", "concurrent", "multi_job"]

GARBAGE_LINES = [
    "this is not json",
    '{"family": 42, "spec": null}',
    '{"truncated": ',
    '"just a string"',
]

RELOAD_FRAMES = [
    {"reload": {"degraded": True}},
    {"reload": {"retry_after_ms": 25}},
    {"reload": {"queue_depth": 16, "degraded": True}},
]


def build_solve(client, k, rng, timeout_ms=None, backend=None):
    req = {
        "client": client,
        "id": f"{client}-{k}",
        "family": rng.choice(FAMILIES),
        "spec": dict(SPEC, job=100.0 + 25.0 * rng.randrange(8)),
        "options": {},
    }
    if req["family"] == "multi_job":
        req["options"]["proc_ready"] = [0.25] * len(SPEC["processors"])
    if timeout_ms is not None:
        req["options"]["timeout_ms"] = timeout_ms
    if backend is not None:
        req["options"]["backend"] = backend
    return json.dumps(req)


class ClientResult:
    def __init__(self):
        self.sent = 0
        self.received = 0
        self.ok = 0
        self.shed = 0
        self.deadline_exceeded = 0
        self.degraded = 0
        self.other_errors = 0
        self.reload_acks = 0
        self.failures = []


def classify(resp, kind, sent_at, deadline_ms, out):
    """Count one response line against the gates."""
    out.received += 1
    if "reloaded" in resp:
        out.reload_acks += 1
        return
    if kind == "timed" and deadline_ms:
        waited_ms = (time.monotonic() - sent_at) * 1e3
        if waited_ms > 2 * deadline_ms:
            out.failures.append(
                f"timed request answered after {waited_ms:.0f}ms, "
                f"deadline was {deadline_ms}ms (> 2x)")
    if resp.get("degraded") is True:
        out.degraded += 1
    err = resp.get("error")
    if err is None:
        if "makespan" in resp:
            out.ok += 1
        return
    k = err.get("kind")
    if k == "overloaded":
        out.shed += 1
        retry = resp.get("retry_after_ms")
        if not isinstance(retry, (int, float)) or not (1 <= retry <= 60_000):
            out.failures.append(f"shed response without a sane retry hint: {resp}")
    elif k == "deadline_exceeded":
        out.deadline_exceeded += 1
    else:
        out.other_errors += 1


def drain(wire, pending, out, deadline_ms):
    """Read one response per pending frame, matching on `seq`."""
    for _ in range(len(pending)):
        line = wire.readline()
        if not line:
            out.failures.append(f"connection closed with {len(pending)} in flight")
            return False
        resp = json.loads(line)
        seq = resp.get("seq")
        if seq not in pending:
            out.failures.append(f"response with unknown seq {seq}: {line[:120]}")
            return False
        kind, sent_at = pending.pop(seq)
        classify(resp, kind, sent_at, deadline_ms, out)
    return True


def run_client(idx, args, results):
    rng = random.Random(args.seed * 1000 + idx)
    out = ClientResult()
    results[idx] = out
    try:
        with socket.create_connection((args.host, args.port), timeout=60) as sock:
            sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
            wire = sock.makefile("rw", encoding="utf-8", newline="\n")
            pending = {}  # seq -> (kind, sent_at)
            seq = 0
            for k in range(args.requests):
                roll = rng.random()
                if idx == 0 and k == 3:
                    # One oversize frame: dropped server-side, answered
                    # with a typed config error, stream must recover.
                    kind, line = "garbage", "x" * (args.oversize_bytes)
                elif roll < 0.60:
                    kind, line = "normal", build_solve(f"chaos-{idx}", k, rng)
                elif roll < 0.75:
                    kind, line = "timed", build_solve(
                        f"chaos-{idx}", k, rng, timeout_ms=args.deadline_ms)
                elif roll < 0.85:
                    # Zero budget on a first-order backend: typed
                    # deadline_exceeded (or absorbed as degraded).
                    kind, line = "timed", build_solve(
                        f"chaos-{idx}", k, rng, timeout_ms=0, backend="pdhg")
                elif roll < 0.95:
                    kind, line = "garbage", rng.choice(GARBAGE_LINES)
                else:
                    kind, line = "reload", json.dumps(rng.choice(RELOAD_FRAMES))
                wire.write(line + "\n")
                wire.flush()
                pending[seq] = (kind, time.monotonic())
                out.sent += 1
                seq += 1
                if len(pending) >= args.window:
                    if not drain(wire, pending, out, args.deadline_ms):
                        return
            drain(wire, pending, out, args.deadline_ms)
    except OSError as e:
        out.failures.append(f"client {idx}: connection error: {e}")


def run_disconnector(idx, args):
    """Send a few frames and vanish without reading; the server must
    absorb the half-closed connection without taking anyone down."""
    rng = random.Random(args.seed * 7000 + idx)
    try:
        sock = socket.create_connection((args.host, args.port), timeout=10)
        wire = sock.makefile("w", encoding="utf-8", newline="\n")
        for k in range(3):
            wire.write(build_solve(f"vanish-{idx}", k, rng) + "\n")
        wire.flush()
        # Half a truncated frame, then an abrupt close.
        sock.sendall(b'{"family": "frontend", "spec"')
        sock.close()
    except OSError:
        pass  # a reset here is the server's prerogative


def final_probe(args):
    """Clean-drain proof: the same server still solves correctly."""
    with socket.create_connection((args.host, args.port), timeout=30) as sock:
        wire = sock.makefile("rw", encoding="utf-8", newline="\n")
        rng = random.Random(args.seed)
        wire.write(build_solve("probe", 0, rng) + "\n")
        # Restore a sane post-chaos config while we are here.
        wire.write(json.dumps({"reload": {"queue_depth": 16}}) + "\n")
        wire.flush()
        saw_solve, saw_ack = False, False
        for _ in range(2):
            resp = json.loads(wire.readline())
            if "makespan" in resp and resp["makespan"] > 0:
                saw_solve = True
            if "reloaded" in resp:
                saw_ack = True
        return saw_solve and saw_ack


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, default=4519)
    ap.add_argument("--seed", type=int, default=42)
    ap.add_argument("--clients", type=int, default=8)
    ap.add_argument("--requests", type=int, default=40, help="frames per client")
    ap.add_argument("--window", type=int, default=4, help="max frames in flight")
    ap.add_argument("--deadline-ms", type=int, default=500)
    ap.add_argument("--oversize-bytes", type=int, default=1024 * 1024 + 64)
    args = ap.parse_args()

    results = [None] * args.clients
    threads = [
        threading.Thread(target=run_client, args=(i, args, results))
        for i in range(args.clients)
    ]
    threads += [
        threading.Thread(target=run_disconnector, args=(i, args)) for i in range(2)
    ]
    for t in threads:
        t.start()
    for t in threads:
        t.join()

    failures = []
    totals = ClientResult()
    for i, r in enumerate(results):
        if r is None:
            failures.append(f"client {i} never ran")
            continue
        failures.extend(r.failures)
        if r.received != r.sent:
            failures.append(
                f"client {i}: lost {r.sent - r.received} of {r.sent} frames")
        for field in ("sent", "received", "ok", "shed", "deadline_exceeded",
                      "degraded", "other_errors", "reload_acks"):
            setattr(totals, field, getattr(totals, field) + getattr(r, field))

    print(f"chaos_smoke: {totals.sent} frames -> {totals.received} responses "
          f"({totals.ok} ok, {totals.shed} shed, "
          f"{totals.deadline_exceeded} deadline_exceeded, "
          f"{totals.degraded} degraded, {totals.other_errors} other errors, "
          f"{totals.reload_acks} reload acks)")

    if totals.deadline_exceeded + totals.degraded == 0:
        failures.append("no deadline_exceeded or degraded response in the "
                        "entire run — the deadline path never engaged")
    try:
        if not final_probe(args):
            failures.append("post-chaos probe did not get a solve + reload ack")
    except (OSError, ValueError) as e:
        failures.append(f"post-chaos probe failed: {e}")

    if failures:
        for f in failures:
            print(f"chaos_smoke: FAIL: {f}", file=sys.stderr)
        return 1
    print("chaos_smoke: ok (lost=0, retry hints finite, deadlines honored, "
          "server survived)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
