"""Layer-2 JAX models, calling the Layer-1 Pallas kernels.

Two compute graphs are AOT-compiled for the rust coordinator:

- ``pdhg_run`` — a fixed-step block of PDHG (Chambolle-Pock) iterations
  for the standardized DLT scheduling LP
  ``min c'x  s.t.  (Ax)_k <= b_k (ineq) / == b_k (eq),  x >= 0``.
  The rust driver (rust/src/pdhg) standardizes + pads the LP, picks
  step sizes from a power-iteration estimate of ||A||, and calls the
  compiled block in a loop until the KKT residuals converge. Everything
  matrix-vector inside goes through the Pallas matvec kernel.

- ``workload`` — the divisible-load work unit executed by cluster
  processors (see kernels/chunk.py).

All arrays are f64 (the rust LP substrate is f64; jax_enable_x64 is set
in aot.py / tests before tracing).
"""

import jax
import jax.numpy as jnp

from compile.kernels.chunk import workload_chunk
from compile.kernels.matvec import matvec


def pdhg_run(a, at, b, c, eq_mask, x0, y0, tau, sigma, *, steps: int):
    """Run ``steps`` PDHG iterations; return iterates and residuals.

    Args:
      a:       (nc, nv) constraint matrix (padded rows: zeros, b=1).
      at:      (nv, nc) transpose (passed in to avoid a transpose op on
               the request path).
      b:       (nc,) right-hand side.
      c:       (nv,) objective (padded cols: +1 keeps padding at zero).
      eq_mask: (nc,) 1.0 where the row is an equality (dual free),
               0.0 for inequality rows (dual projected onto y >= 0).
      x0, y0:  warm-start iterates.
      tau, sigma: scalar step sizes with tau*sigma*||A||^2 < 1.
      steps:   static iteration count per compiled call.

    Returns:
      (x, y, primal_res, dual_res, gap): final iterates, infinity-norm
      primal feasibility residual, dual stationarity residual, and
      |c'x + b'y| duality gap surrogate.
    """

    def step(carry, _):
        x, y = carry
        xn = jnp.maximum(x - tau * (c + matvec(at, y)), 0.0)
        z = 2.0 * xn - x
        yn = y + sigma * (matvec(a, z) - b)
        yn = jnp.where(eq_mask > 0.5, yn, jnp.maximum(yn, 0.0))
        return (xn, yn), None

    (x, y), _ = jax.lax.scan(step, (x0, y0), None, length=steps)

    ax_b = matvec(a, x) - b
    primal = jnp.max(jnp.where(eq_mask > 0.5, jnp.abs(ax_b), jnp.maximum(ax_b, 0.0)))
    station = c + matvec(at, y)
    dual = jnp.max(jnp.maximum(-station, 0.0))
    gap = jnp.abs(jnp.dot(c, x) + jnp.dot(b, y))
    return x, y, primal, dual, gap


def workload(data, weights):
    """The divisible-load work unit (tuple-wrapped for AOT export)."""
    return (workload_chunk(data, weights),)


def pdhg_fn(steps: int):
    """Tuple-returning wrapper for AOT export with a fixed step count."""

    def fn(a, at, b, c, eq_mask, x0, y0, tau, sigma):
        return pdhg_run(a, at, b, c, eq_mask, x0, y0, tau, sigma, steps=steps)

    return fn
