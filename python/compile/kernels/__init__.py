"""Layer-1 Pallas kernels (build-time only; lowered into the L2 HLO)."""

from compile.kernels.chunk import workload_chunk
from compile.kernels.matvec import matvec

__all__ = ["matvec", "workload_chunk"]
