"""Layer-1 Pallas kernel: the divisible-load work unit.

The paper's processors burn through "arbitrarily divisible" data. The
motivating applications (§1.2) are image feature extraction and video
processing: per-chunk, embarrassingly parallel compute. This kernel is
that work unit — a feature-extraction-like pipeline over one data
chunk:

    scores = sum_axis1( relu( chunk @ weights ) )

Tiled over row blocks; the weight matrix stays resident in VMEM across
the grid (it is a broadcast block), the chunk streams through. One
execution of the compiled artifact == one work unit; the cluster's
processors run ``ceil(load * units_per_load)`` executions per received
fraction, which is how an abstract ``A_j`` maps onto real compute.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_R = 128


def _chunk_kernel(d_ref, w_ref, o_ref):
    """One row-block: matmul against the full weight tile, ReLU, reduce."""
    acc = jnp.maximum(d_ref[...] @ w_ref[...], 0.0)
    o_ref[...] = jnp.sum(acc, axis=1)


def _pick_block(dim: int, preferred: int) -> int:
    b = min(preferred, dim)
    while dim % b != 0:
        b -= 1
    return b


@functools.partial(jax.jit, static_argnames=("block_r",))
def workload_chunk(data, weights, *, block_r: int = DEFAULT_BLOCK_R):
    """Feature scores for one chunk. ``data``: (r, c), ``weights``: (c, c)."""
    r, c = data.shape
    assert weights.shape == (c, c), f"weights {weights.shape} != ({c},{c})"
    br = _pick_block(r, block_r)
    return pl.pallas_call(
        _chunk_kernel,
        grid=(r // br,),
        in_specs=[
            pl.BlockSpec((br, c), lambda i: (i, 0)),
            pl.BlockSpec((c, c), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((br,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((r,), data.dtype),
        interpret=True,
    )(data, weights)
