"""Layer-1 Pallas kernel: blocked dense matrix-vector product.

This is the hot spot of the PDHG LP solver (two matvecs per iteration).
The kernel tiles ``A`` into ``(bm, bk)`` VMEM blocks and accumulates
partial dot products over the ``k`` grid dimension — the BlockSpec
expresses the HBM->VMEM schedule that a CUDA implementation would do
with threadblocks.

``interpret=True`` everywhere: the CPU PJRT plugin cannot execute
Mosaic custom-calls; interpret mode lowers the kernel to plain HLO so
the AOT artifact runs on the rust CPU client. On a real TPU the same
BlockSpecs compile via Mosaic (see DESIGN.md §Hardware-Adaptation).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default VMEM tile. 128 is the MXU-native lane width; a (128, 128) f32
# block is 64 KiB, so A-block + x-block + out-block stay far below the
# ~16 MiB VMEM budget even with double buffering.
DEFAULT_BLOCK_M = 128
DEFAULT_BLOCK_K = 128


def _matvec_kernel(a_ref, x_ref, o_ref):
    """One (bm, bk) tile: accumulate a_ref @ x_ref into o_ref."""
    k = pl.program_id(1)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += a_ref[...] @ x_ref[...]


def _pick_block(dim: int, preferred: int) -> int:
    """Largest block <= preferred that divides dim."""
    b = min(preferred, dim)
    while dim % b != 0:
        b -= 1
    return b


@functools.partial(jax.jit, static_argnames=("block_m", "block_k"))
def matvec(a, x, *, block_m: int = DEFAULT_BLOCK_M, block_k: int = DEFAULT_BLOCK_K):
    """``a @ x`` via the blocked Pallas kernel.

    ``a``: (m, k), ``x``: (k,). Shapes need not be multiples of the
    block; the largest divisor <= the preferred block is used.
    """
    m, k = a.shape
    assert x.shape == (k,), f"shape mismatch: {a.shape} @ {x.shape}"
    bm = _pick_block(m, block_m)
    bk = _pick_block(k, block_k)
    grid = (m // bm, k // bk)
    return pl.pallas_call(
        _matvec_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j: (i, j)),
            pl.BlockSpec((bk,), lambda i, j: (j,)),
        ],
        out_specs=pl.BlockSpec((bm,), lambda i, j: (i,)),
        out_shape=jax.ShapeDtypeStruct((m,), a.dtype),
        interpret=True,
    )(a, x)
