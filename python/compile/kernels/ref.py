"""Pure-jnp oracles for the Pallas kernels.

Every kernel in this package has its reference implementation here;
``python/tests`` sweeps shapes and dtypes (hypothesis) asserting
``assert_allclose(kernel, ref)``.
"""

import jax.numpy as jnp


def matvec_ref(a, x):
    """Reference for ``matvec.matvec``."""
    return a @ x


def workload_chunk_ref(data, weights):
    """Reference for ``chunk.workload_chunk``."""
    return jnp.sum(jnp.maximum(data @ weights, 0.0), axis=1)


def pdhg_step_ref(a, at, b, c, eq_mask, x, y, tau, sigma):
    """One PDHG iteration, textbook form (reference for model.pdhg_run).

    LP: min c'x  s.t.  (Ax)_k <= b_k (ineq rows) / == b_k (eq rows),
    x >= 0. Chambolle-Pock with over-relaxation z = 2x' - x.
    """
    xn = jnp.maximum(x - tau * (c + at @ y), 0.0)
    z = 2.0 * xn - x
    yn = y + sigma * (a @ z - b)
    yn = jnp.where(eq_mask, yn, jnp.maximum(yn, 0.0))
    return xn, yn
