"""AOT compilation: lower the L2 models to HLO text artifacts.

HLO *text* (not serialized HloModuleProto) is the interchange format:
jax >= 0.5 emits protos with 64-bit instruction ids which the rust
side's XLA (xla_extension 0.5.1) rejects; the text parser reassigns ids
and round-trips cleanly. See /opt/xla-example/README.md.

Usage:  cd python && python -m compile.aot --out-dir ../artifacts

Emits:
  pdhg_nv{NV}_nc{NC}_s{STEPS}.hlo.txt   (one per padded LP shape)
  workload_r{R}_c{C}.hlo.txt            (the per-unit compute kernel)
  manifest.json                         (shapes + metadata for rust)
"""

import argparse
import json
import os

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp  # noqa: E402
from jax._src.lib import xla_client as xc  # noqa: E402

from compile import model  # noqa: E402

# Padded LP shape variants (nv = variables, nc = constraint rows).
# Small covers every sweep in the paper (N<=3, M<=20 -> NFE needs
# 181 vars / 183 rows); large covers the solver-scaling benches.
PDHG_VARIANTS = [
    (128, 192),
    (256, 384),
    (512, 768),
]
PDHG_STEPS = 200

WORKLOAD_SHAPE = (128, 128)  # rows x cols, f32


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_pdhg(nv: int, nc: int, steps: int) -> str:
    f64 = jnp.float64
    spec = jax.ShapeDtypeStruct
    lowered = jax.jit(model.pdhg_fn(steps)).lower(
        spec((nc, nv), f64),  # a
        spec((nv, nc), f64),  # at
        spec((nc,), f64),     # b
        spec((nv,), f64),     # c
        spec((nc,), f64),     # eq_mask
        spec((nv,), f64),     # x0
        spec((nc,), f64),     # y0
        spec((), f64),        # tau
        spec((), f64),        # sigma
    )
    return to_hlo_text(lowered)


def lower_workload(rows: int, cols: int) -> str:
    f32 = jnp.float32
    spec = jax.ShapeDtypeStruct
    lowered = jax.jit(model.workload).lower(
        spec((rows, cols), f32), spec((cols, cols), f32)
    )
    return to_hlo_text(lowered)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--steps", type=int, default=PDHG_STEPS)
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    manifest = {"pdhg": [], "workload": []}

    for nv, nc in PDHG_VARIANTS:
        name = f"pdhg_nv{nv}_nc{nc}_s{args.steps}"
        text = lower_pdhg(nv, nc, args.steps)
        path = os.path.join(args.out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        manifest["pdhg"].append(
            {"name": name, "file": f"{name}.hlo.txt", "nv": nv, "nc": nc,
             "steps": args.steps, "dtype": "f64"}
        )
        print(f"wrote {path} ({len(text)} chars)")

    r, c = WORKLOAD_SHAPE
    name = f"workload_r{r}_c{c}"
    text = lower_workload(r, c)
    path = os.path.join(args.out_dir, f"{name}.hlo.txt")
    with open(path, "w") as f:
        f.write(text)
    manifest["workload"].append(
        {"name": name, "file": f"{name}.hlo.txt", "rows": r, "cols": c,
         "dtype": "f32"}
    )
    print(f"wrote {path} ({len(text)} chars)")

    mpath = os.path.join(args.out_dir, "manifest.json")
    with open(mpath, "w") as f:
        json.dump(manifest, f, indent=2)
        f.write("\n")
    print(f"wrote {mpath}")


if __name__ == "__main__":
    main()
