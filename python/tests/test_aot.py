"""AOT lowering: HLO text artifacts are well-formed and complete."""

import json
import os

import pytest

from compile import aot


def test_pdhg_lowers_to_hlo_text():
    text = aot.lower_pdhg(32, 48, steps=5)
    assert "HloModule" in text
    assert "ENTRY" in text
    # f64 artifact
    assert "f64" in text
    # the fixed-step scan lowers to a while loop
    assert "while" in text


def test_workload_lowers_to_hlo_text():
    text = aot.lower_workload(64, 64)
    assert "HloModule" in text
    assert "f32" in text


def test_manifest_written(tmp_path):
    import subprocess
    import sys

    out = tmp_path / "artifacts"
    subprocess.run(
        [sys.executable, "-m", "compile.aot", "--out-dir", str(out), "--steps", "5"],
        check=True,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    )
    manifest = json.loads((out / "manifest.json").read_text())
    assert len(manifest["pdhg"]) == len(aot.PDHG_VARIANTS)
    assert len(manifest["workload"]) == 1
    for entry in manifest["pdhg"]:
        f = out / entry["file"]
        assert f.exists()
        assert "HloModule" in f.read_text()[:200]


@pytest.mark.parametrize("nv,nc", aot.PDHG_VARIANTS)
def test_variant_shapes_appear_in_hlo(nv, nc):
    text = aot.lower_pdhg(nv, nc, steps=2)
    assert f"f64[{nc},{nv}]" in text, "constraint matrix shape missing"
    assert f"f64[{nv}]" in text
