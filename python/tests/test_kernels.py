"""Hypothesis sweeps: Pallas kernels vs pure-jnp oracles.

The core Layer-1 correctness signal: for every (shape, dtype, block)
combination, the blocked Pallas kernel must agree with ref.py.
"""

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.chunk import workload_chunk
from compile.kernels.matvec import matvec

DTYPES = [np.float32, np.float64]


def tol(dtype):
    return dict(rtol=2e-4, atol=2e-4) if dtype == np.float32 else dict(rtol=1e-9, atol=1e-9)


@settings(max_examples=40, deadline=None)
@given(
    m=st.integers(1, 300),
    k=st.integers(1, 300),
    dtype=st.sampled_from(DTYPES),
    seed=st.integers(0, 2**31 - 1),
)
def test_matvec_matches_ref(m, k, dtype, seed):
    rng = np.random.default_rng(seed)
    a = rng.standard_normal((m, k)).astype(dtype)
    x = rng.standard_normal(k).astype(dtype)
    got = matvec(jnp.asarray(a), jnp.asarray(x))
    want = ref.matvec_ref(a, x)
    assert got.dtype == a.dtype
    np.testing.assert_allclose(np.asarray(got), want, **tol(dtype))


@settings(max_examples=25, deadline=None)
@given(
    m=st.sampled_from([64, 128, 256, 384]),
    k=st.sampled_from([64, 128, 256]),
    bm=st.sampled_from([32, 64, 128]),
    bk=st.sampled_from([32, 64, 128]),
    seed=st.integers(0, 2**31 - 1),
)
def test_matvec_block_size_invariance(m, k, bm, bk, seed):
    """The result must not depend on the tiling."""
    rng = np.random.default_rng(seed)
    a = rng.standard_normal((m, k))
    x = rng.standard_normal(k)
    got = matvec(jnp.asarray(a), jnp.asarray(x), block_m=bm, block_k=bk)
    np.testing.assert_allclose(np.asarray(got), ref.matvec_ref(a, x), rtol=1e-9, atol=1e-9)


def test_matvec_rejects_bad_shapes():
    with pytest.raises(AssertionError):
        matvec(jnp.zeros((4, 5)), jnp.zeros(6))


@settings(max_examples=25, deadline=None)
@given(
    r=st.sampled_from([1, 7, 64, 128, 200, 256]),
    c=st.sampled_from([16, 64, 128]),
    dtype=st.sampled_from(DTYPES),
    seed=st.integers(0, 2**31 - 1),
)
def test_workload_chunk_matches_ref(r, c, dtype, seed):
    rng = np.random.default_rng(seed)
    d = rng.standard_normal((r, c)).astype(dtype)
    w = rng.standard_normal((c, c)).astype(dtype)
    got = workload_chunk(jnp.asarray(d), jnp.asarray(w))
    want = ref.workload_chunk_ref(d, w)
    rt = dict(rtol=5e-3, atol=5e-3) if dtype == np.float32 else dict(rtol=1e-9, atol=1e-9)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), **rt)


def test_workload_chunk_nonnegative():
    rng = np.random.default_rng(0)
    d = rng.standard_normal((128, 128)).astype(np.float32)
    w = rng.standard_normal((128, 128)).astype(np.float32)
    out = np.asarray(workload_chunk(jnp.asarray(d), jnp.asarray(w)))
    assert (out >= 0).all(), "ReLU + sum of nonnegatives must be >= 0"


def test_matvec_zero_matrix():
    got = matvec(jnp.zeros((32, 48)), jnp.ones(48))
    np.testing.assert_array_equal(np.asarray(got), np.zeros(32))
