"""PDHG (Layer-2 model) vs scipy.linprog on randomized LPs.

The rust driver consumes the AOT artifact of ``model.pdhg_fn``; these
tests validate the algorithm itself (same code path, traced in-process)
against an exact simplex/HiGHS oracle.
"""

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st
from scipy.optimize import linprog

from compile import model


def solve_pdhg(a, b, c, eq_mask, rounds=40, steps=200):
    """Drive pdhg_run the way the rust driver does: fixed-step blocks
    until the residuals are small."""
    nc, nv = a.shape
    norm = np.linalg.norm(a, 2)
    tau = sigma = 0.9 / max(norm, 1e-12)
    x = jnp.zeros(nv)
    y = jnp.zeros(nc)
    aj = jnp.asarray(a)
    atj = jnp.asarray(a.T)
    bj = jnp.asarray(b)
    cj = jnp.asarray(c)
    mj = jnp.asarray(eq_mask)
    for _ in range(rounds):
        x, y, primal, dual, gap = model.pdhg_run(
            aj, atj, bj, cj, mj, x, y, jnp.float64(tau), jnp.float64(sigma), steps=steps
        )
        scale = 1.0 + max(abs(float(jnp.dot(cj, x))), 1.0)
        if float(primal) < 1e-7 and float(dual) < 1e-7 and float(gap) < 1e-6 * scale:
            break
    return np.asarray(x), float(primal), float(dual)


def random_lp(rng, nv, nc_ineq):
    """Random feasible, bounded LP with one equality (mass) row —
    the same shape class as the paper's scheduling LPs."""
    a_ineq = rng.uniform(-1.0, 1.0, size=(nc_ineq, nv))
    x_feas = rng.uniform(0.0, 2.0, size=nv)
    b_ineq = a_ineq @ x_feas + rng.uniform(0.1, 1.0, size=nc_ineq)
    mass = x_feas.sum()
    a = np.vstack([a_ineq, np.ones((1, nv))])
    b = np.concatenate([b_ineq, [mass]])
    eq = np.zeros(nc_ineq + 1)
    eq[-1] = 1.0
    c = rng.uniform(0.1, 2.0, size=nv)
    return a, b, c, eq


@settings(max_examples=8, deadline=None)
@given(
    nv=st.integers(4, 24),
    nc=st.integers(2, 16),
    seed=st.integers(0, 2**31 - 1),
)
def test_pdhg_matches_scipy_on_random_lps(nv, nc, seed):
    rng = np.random.default_rng(seed)
    a, b, c, eq = random_lp(rng, nv, nc)
    x, primal, dual = solve_pdhg(a, b, c, eq)

    res = linprog(
        c,
        A_ub=a[:-1],
        b_ub=b[:-1],
        A_eq=a[-1:],
        b_eq=b[-1:],
        bounds=[(0, None)] * nv,
        method="highs",
    )
    assert res.status == 0, f"scipy failed: {res.message}"
    obj_pdhg = float(c @ x)
    assert primal < 1e-5, f"primal residual {primal}"
    # First-order methods: accept ~0.1% relative objective gap.
    assert obj_pdhg <= res.fun + 1e-3 * max(abs(res.fun), 1.0) + 1e-6, (
        f"pdhg {obj_pdhg} vs scipy {res.fun}"
    )


def test_pdhg_on_dlt_shaped_lp():
    """A hand-built instance of the paper's §3.1 LP (N=2, M=3)."""
    g = [0.2, 0.4]
    r = [1.0, 2.0]
    a_speed = [2.0, 3.0, 4.0]
    job = 10.0
    n, m = 2, 3
    nv = n * m + 1  # betas + T_f
    tf = n * m

    rows, rhs, eq = [], [], []

    def bidx(i, j):
        return i * m + j

    # release: -beta[0][0]*A_1 <= -(R_2 - R_1)
    row = np.zeros(nv)
    row[bidx(0, 0)] = -a_speed[0]
    rows.append(row)
    rhs.append(-(r[1] - r[0]))
    eq.append(0.0)
    # continuity
    for i in range(n - 1):
        for j in range(m - 1):
            row = np.zeros(nv)
            row[bidx(i, j)] = a_speed[j] - g[i]
            row[bidx(i + 1, j)] = g[i + 1]
            row[bidx(i, j + 1)] = -a_speed[j + 1]
            rows.append(row)
            rhs.append(0.0)
            eq.append(0.0)
    # finish: -T_f + sum_{k<j} beta[0][k] G_1 + sum_i beta[i][j] A_j <= -R_1
    for j in range(m):
        row = np.zeros(nv)
        row[tf] = -1.0
        for k in range(j):
            row[bidx(0, k)] = g[0]
        for i in range(n):
            row[bidx(i, j)] += a_speed[j]
        rows.append(row)
        rhs.append(-r[0])
        eq.append(0.0)
    # normalize
    row = np.zeros(nv)
    row[: n * m] = 1.0
    rows.append(row)
    rhs.append(job)
    eq.append(1.0)

    a = np.array(rows)
    b = np.array(rhs)
    c = np.zeros(nv)
    c[tf] = 1.0
    x, primal, dual = solve_pdhg(a, b, c, np.array(eq), rounds=80)

    res = linprog(
        c,
        A_ub=a[np.array(eq) == 0.0],
        b_ub=b[np.array(eq) == 0.0],
        A_eq=a[np.array(eq) == 1.0],
        b_eq=b[np.array(eq) == 1.0],
        bounds=[(0, None)] * nv,
        method="highs",
    )
    assert res.status == 0
    assert abs(x[tf] - res.fun) < 2e-3 * max(res.fun, 1.0), (
        f"pdhg T_f {x[tf]} vs scipy {res.fun}"
    )


def test_pdhg_padding_is_inert():
    """Zero rows (b=1) and +1-cost columns must not change the optimum —
    this is the padding contract the rust driver relies on."""
    rng = np.random.default_rng(42)
    a, b, c, eq = random_lp(rng, 8, 6)
    x0, _, _ = solve_pdhg(a, b, c, eq)

    nv_pad, nc_pad = 16, 12
    a_pad = np.zeros((nc_pad, nv_pad))
    a_pad[: a.shape[0], : a.shape[1]] = a
    b_pad = np.ones(nc_pad)
    b_pad[: len(b)] = b
    c_pad = np.ones(nv_pad)
    c_pad[: len(c)] = c
    eq_pad = np.zeros(nc_pad)
    eq_pad[: len(eq)] = eq
    x1, _, _ = solve_pdhg(a_pad, b_pad, c_pad, eq_pad)

    np.testing.assert_allclose(
        float(c @ x0), float(c_pad @ x1), rtol=5e-3, atol=5e-3
    )
    np.testing.assert_allclose(x1[a.shape[1]:], 0.0, atol=1e-6)
